//! Stub of the `xla` (PJRT) bindings used by `daq::runtime`.
//!
//! The real crate wraps the XLA C API and is only available on testbeds
//! with the XLA toolchain baked in. This stub is type-compatible with
//! every call site in `daq::runtime` but fails at the earliest entry
//! point ([`PjRtClient::cpu`]), so `Runtime::open` returns an error and
//! all PJRT-dependent code paths take their documented
//! "skipped (run `make artifacts`)" branches. Swap the `xla` path
//! dependency in `Cargo.toml` for the real bindings to enable PJRT.

use std::path::Path;

/// Stub error: carries a message; call sites format it with `{:?}`.
pub struct Error(pub String);

impl std::fmt::Debug for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.0)
    }
}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable() -> Error {
    Error("xla stub: PJRT bindings not available in this build (link the real `xla` crate)".into())
}

/// A host literal (stub: holds nothing; never observable because no
/// executable can be built).
pub struct Literal(());

impl Literal {
    pub fn vec1<T: Copy>(_data: &[T]) -> Literal {
        Literal(())
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Ok(Literal(()))
    }

    pub fn to_tuple1(self) -> Result<Literal> {
        Err(unavailable())
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(unavailable())
    }
}

pub struct HloModuleProto(());

impl HloModuleProto {
    pub fn from_text_file(_path: impl AsRef<Path>) -> Result<HloModuleProto> {
        Err(unavailable())
    }
}

pub struct XlaComputation(());

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation(())
    }
}

pub struct PjRtBuffer(());

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable())
    }
}

pub struct PjRtLoadedExecutable(());

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable())
    }
}

pub struct PjRtClient(());

impl PjRtClient {
    /// Always fails in the stub — the one gate every PJRT path goes
    /// through (`daq::runtime::Runtime::open`).
    pub fn cpu() -> Result<PjRtClient> {
        Err(unavailable())
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_fails_politely() {
        let e = PjRtClient::cpu().err().expect("stub must fail");
        assert!(format!("{e:?}").contains("stub"));
    }

    #[test]
    fn literal_shapes_are_inert() {
        let l = Literal::vec1(&[1.0f32, 2.0]);
        assert!(l.reshape(&[2]).is_ok());
        assert!(l.to_vec::<f32>().is_err());
    }
}
