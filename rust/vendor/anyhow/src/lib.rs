//! Offline stand-in for the `anyhow` crate, covering exactly the surface
//! this repository uses: [`Error`], [`Result`], the [`anyhow!`] /
//! [`bail!`] macros, and the [`Context`] extension trait.
//!
//! The registry of this build environment does not carry `anyhow`; the
//! semantics here match it for every call site in the tree: `?` converts
//! any `std::error::Error + Send + Sync + 'static`, `context` /
//! `with_context` prepend a message, `{e}` prints the outermost message
//! and `{e:#}` the whole cause chain joined by `": "`.

use std::fmt;

/// A dynamic error: an outermost message plus its cause chain.
pub struct Error {
    /// Outermost context first.
    chain: Vec<String>,
}

impl Error {
    /// Build an error from a displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    /// Prepend a context message (what `Context::context` attaches).
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            f.write_str(&self.chain.join(": "))
        } else {
            f.write_str(&self.chain[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.chain.join(": "))
    }
}

// `Error` deliberately does NOT implement `std::error::Error`, which is
// what keeps this blanket conversion coherent (same trick as upstream).
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

/// `anyhow::Result<T>` — `Result` with [`Error`] as the default error.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(..)` / `.with_context(..)`.
pub trait Context<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E: Into<Error>> Context<T, E> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T, Error> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or a displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(::std::format!($msg))
    };
    ($fmt:literal, $($arg:tt)*) => {
        $crate::Error::msg(::std::format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// `return Err(anyhow!(..))`.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing file")
    }

    #[test]
    fn display_and_alternate_chain() {
        let e: Error = io_err().into();
        let e = e.context("load checkpoint");
        assert_eq!(format!("{e}"), "load checkpoint");
        assert_eq!(format!("{e:#}"), "load checkpoint: missing file");
    }

    #[test]
    fn question_mark_converts() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        assert!(inner().is_err());
    }

    #[test]
    fn macros_build_errors() {
        let x = 3;
        let a = anyhow!("plain");
        let b = anyhow!("value {x} and {}", 4);
        let c = anyhow!(String::from("owned"));
        assert_eq!(format!("{a}"), "plain");
        assert_eq!(format!("{b}"), "value 3 and 4");
        assert_eq!(format!("{c}"), "owned");

        fn bailer() -> Result<()> {
            bail!("failed with {x}", x = 7);
        }
        assert_eq!(format!("{}", bailer().unwrap_err()), "failed with 7");
    }

    #[test]
    fn context_on_result_and_option() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.with_context(|| format!("step {}", 2)).unwrap_err();
        assert_eq!(format!("{e:#}"), "step 2: missing file");
        let o: Option<u8> = None;
        assert_eq!(format!("{}", o.context("empty").unwrap_err()), "empty");
    }
}
