//! Quickstart: DAQ on a single weight matrix — no artifacts required.
//!
//! Builds a synthetic (base, post) pair in the paper's small-delta regime,
//! quantizes with plain AbsMax FP8, then runs Algorithm 1 under all three
//! objectives and prints what each metric favours.
//!
//! Run: `cargo run --release --example quickstart`

use daq::metrics::sweep_native;
use daq::quant::{absmax_scales, quantize_with_scales, Granularity};
use daq::report::{fmt3, fmt_pct, Table};
use daq::search::{search_scale_with, NativeSweep, Objective, SearchConfig};
use daq::tensor::Tensor;
use daq::util::rng::XorShift;

fn main() {
    // W_base: a realistic weight matrix; W_post = W_base + small delta
    // (the paper's post-training regime: ||dW|| << ||W||)
    let (rows, cols) = (256usize, 256usize);
    let mut rng = XorShift::new(7);
    let wb = Tensor::new(vec![rows, cols], rng.normal_vec(rows * cols, 0.08));
    let wp = Tensor::new(
        vec![rows, cols],
        wb.data().iter().map(|&b| b + rng.normal() * 0.0015).collect(),
    );
    println!(
        "synthetic pair: ||W||={:.2}  ||dW||={:.4}  ratio={:.3}%\n",
        wb.norm(),
        wp.sub(&wb).norm(),
        100.0 * wp.sub(&wb).norm() / wb.norm()
    );

    let gran = Granularity::Block(128);
    let s0 = absmax_scales(&wp, gran);

    // Baseline: AbsMax (alpha = 1)
    let st = sweep_native(&wp, &wb, &s0, &[1.0])[0];
    let mut t = Table::new(
        "AbsMax FP8 (block-128) vs DAQ scale search",
        &["config", "alpha", "SignRate", "CosSim", "MSE", "dW L2"],
    );
    t.row(vec![
        "AbsMax (no search)".into(),
        "1.0000".into(),
        fmt_pct(st.sign_rate()),
        fmt3(st.cos_sim()),
        format!("{:.3e}", st.mse()),
        format!("{:.4}", st.delta_l2()),
    ]);

    // Algorithm 1 under each objective
    for obj in [Objective::NegMse, Objective::SignRate, Objective::CosSim] {
        let cfg = SearchConfig::paper_default(obj, (0.8, 1.25));
        let res = search_scale_with(&NativeSweep, &wp, &wb, &s0, &cfg);
        t.row(vec![
            format!("search: {}", obj.label()),
            format!("{:.4}", res.alpha),
            fmt_pct(res.stats.sign_rate()),
            fmt3(res.stats.cos_sim()),
            format!("{:.3e}", res.stats.mse()),
            format!("{:.4}", res.stats.delta_l2()),
        ]);
    }
    println!("{}", t.render());

    // Store the winner in the compact FP8 format
    let cfg = SearchConfig::paper_default(Objective::SignRate, (0.8, 1.25));
    let res = search_scale_with(&NativeSweep, &wp, &wb, &s0, &cfg);
    let q = quantize_with_scales(&wp, &s0, res.alpha);
    println!(
        "stored: {} codes + {} scales = {} bytes ({:.2}x compression vs f32)",
        q.codes.len(),
        q.scales.scales.len(),
        q.nbytes(),
        q.compression_ratio()
    );
    println!(
        "\nNote the paper's core observation: the MSE-optimal scale is NOT \
         the delta-optimal scale —\nsign search trades a little \
         reconstruction error for markedly better delta fidelity."
    );
}
