//! SFT style recovery — the paper's headline experiment (§3) on the real
//! trained checkpoints: standard FP8 quantization loses the SFT style;
//! DAQ's delta-aware scale search recovers it; MSE search makes it worse.
//!
//! Requires `make artifacts`. Run:
//! `cargo run --release --example sft_style_recovery [-- pjrt]`

use daq::coordinator::Method;
use daq::eval::load_params;
use daq::experiments::Lab;
use daq::quant::Granularity;
use daq::report::{fmt3, Table};
use daq::search::Objective;

fn main() -> anyhow::Result<()> {
    let use_pjrt = std::env::args().any(|a| a == "pjrt");
    let lab = Lab::open("artifacts", use_pjrt)?;

    println!("loaded: {} quantizable layers, eval sets style={} general={}\n",
             lab.quantizable.len(), lab.style.n, lab.general.n);

    let mut t = Table::new(
        "Style knowledge under FP8 quantization (block-128)",
        &["model", "Style", "General"],
    );

    let (s, g) = lab.rubric(&load_params(&lab.base)?)?;
    t.row(vec!["base (f32)".into(), fmt3(s), fmt3(g)]);
    let (s, g) = lab.rubric(&load_params(&lab.post)?)?;
    t.row(vec!["post-trained (f32)".into(), fmt3(s), fmt3(g)]);
    let post_style = s;

    let gran = Granularity::Block(128);
    let out = lab.quantize(gran, Method::AbsMax)?;
    let (s, g) = lab.rubric(&out.params)?;
    t.row(vec!["absmax FP8".into(), fmt3(s), fmt3(g)]);
    let absmax_style = s;

    let range = (0.8f32, 1.25f32);
    let mut styles = std::collections::BTreeMap::new();
    for obj in [Objective::NegMse, Objective::SignRate, Objective::CosSim] {
        let out = lab.quantize(gran, Method::Search { objective: obj, range })?;
        let (s, g) = lab.rubric(&out.params)?;
        t.row(vec![format!("search {} FP8", obj.label()), fmt3(s), fmt3(g)]);
        styles.insert(obj.label(), s);
    }
    println!("{}", t.render());

    println!("paper-shape checks:");
    let drop = post_style - absmax_style;
    println!(
        "  [{}] AbsMax degrades Style (drop {:.3})",
        if drop > 0.05 { "ok" } else { "??" },
        drop
    );
    println!(
        "  [{}] DAQ-sign recovers over AbsMax ({:.3} -> {:.3})",
        if styles["sign"] > absmax_style { "ok" } else { "??" },
        absmax_style, styles["sign"]
    );
    println!(
        "  [{}] DAQ-cos recovers over AbsMax ({:.3} -> {:.3})",
        if styles["cos"] > absmax_style { "ok" } else { "??" },
        absmax_style, styles["cos"]
    );
    println!(
        "  [{}] MSE search does NOT recover ({:.3} vs absmax {:.3})",
        if styles["mse"] <= absmax_style + 0.05 { "ok" } else { "??" },
        styles["mse"], absmax_style
    );
    Ok(())
}
