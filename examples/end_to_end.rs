//! End-to-end driver (DESIGN.md "End-to-end validation"): exercises every
//! layer of the stack on the real workload, in order:
//!
//!   1. FP8 golden cross-check (Rust codec ≡ JAX/Pallas codec, bit-exact)
//!   2. PJRT runtime loads the AOT artifacts, cross-checks the Pallas
//!      sweep kernel against the native engine on a real layer
//!   3. Quantization pipeline: AbsMax baseline vs MSE search vs DAQ
//!      (sign & cosine), block + channel
//!   4. Rubric evaluation (Style / General) of every variant
//!   5. Batched serving of the DAQ-quantized model with latency stats
//!
//! The printed summary is the source for EXPERIMENTS.md. Requires
//! `make artifacts`. Run: `cargo run --release --example end_to_end`

use daq::coordinator::Method;
use daq::eval::{load_params, params_bytes, PjrtForward};
use daq::experiments::{Lab, PAPER_RANGES};
use daq::fp8;
use daq::io::dts::Dts;
use daq::metrics::sweep_native;
use daq::quant::{absmax_scales, Granularity};
use daq::report::{fmt3, fmt_l2, fmt_pct, Table};
use daq::search::Objective;
use daq::serve::{gen_requests, serve_reforward};
use daq::util::telemetry::{self, Telemetry};

/// Phase timing via the telemetry registry: wall time lands in a
/// `<name>.seconds` histogram, so the end-of-run phase-attribution table
/// is the same one `daq quantize`/`daq serve` print.
fn measure<T>(tel: &Telemetry, name: &str, f: impl FnOnce() -> T) -> T {
    let _t = tel.histogram(&format!("{name}.seconds")).start_timer();
    f()
}

fn main() -> anyhow::Result<()> {
    let tel = Telemetry::new("end-to-end");
    let _ctx = telemetry::set_current(tel.clone());
    let dir = std::env::var("DAQ_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());

    // ---- 1. codec golden cross-check ----
    measure(&tel, "1. fp8 golden cross-check", || -> anyhow::Result<()> {
        let d = Dts::read(format!("{dir}/fp8_golden.dts"))?;
        let inputs = d.tensor_f32("inputs")?.into_data();
        let qdq = d.tensor_f32("qdq")?.into_data();
        let (_, codes) = d.tensor_u8("codes")?;
        for i in 0..inputs.len() {
            assert_eq!(fp8::qdq_e4m3(inputs[i]).to_bits(), qdq[i].to_bits(),
                       "qdq mismatch at {i}: {}", inputs[i]);
            assert_eq!(fp8::encode_e4m3(inputs[i]), codes[i],
                       "encode mismatch at {i}");
        }
        println!("   codec bit-exact on {} golden vectors", inputs.len());
        Ok(())
    })?;

    // ---- 2. PJRT runtime + kernel cross-check ----
    let lab = measure(&tel, "2. open lab (PJRT)", || Lab::open(&dir, true))?;
    let rt = lab.rt.as_ref().unwrap();
    println!("   PJRT platform: {}", rt.platform());
    measure(&tel, "2b. pallas sweep == native sweep", || -> anyhow::Result<()> {
        let name = &lab.quantizable[0];
        let wp = lab.post.tensor_f32(name)?;
        let wb = lab.base.tensor_f32(name)?;
        let s0 = absmax_scales(&wp, Granularity::Block(128));
        let alphas: Vec<f32> = (0..16).map(|i| 0.8 + 0.03 * i as f32).collect();
        let native = sweep_native(&wp, &wb, &s0, &alphas);
        let pjrt = rt.sweep(&wp, &wb, &s0.expand(), &alphas)?;
        for (a, b) in native.iter().zip(&pjrt) {
            assert!((a.agree - b.agree).abs() <= 2.0,
                    "sign counts must agree to O(1): {} vs {}", a.agree, b.agree);
            assert!((a.dot - b.dot).abs() <= 1e-4 * a.dot.abs().max(1.0));
            assert!((a.sq - b.sq).abs() <= 1e-3 * a.sq.abs().max(1e-9));
        }
        println!("   layer {name}: 16-candidate sweep agrees (native vs Pallas)");
        Ok(())
    })?;

    // ---- 3+4. pipeline variants + rubric ----
    let mut table = Table::new(
        "End-to-end: quantization variants on the SFT model",
        &["variant", "dW L2", "SignRate", "CosSim", "Style", "General"],
    );
    let (s, g) = lab.rubric(&load_params(&lab.base)?)?;
    table.row(vec!["base (f32)".into(), "-".into(), "-".into(), "-".into(),
                   fmt3(s), fmt3(g)]);
    let (s, g) = lab.rubric(&load_params(&lab.post)?)?;
    table.row(vec!["post-trained (f32)".into(), "0".into(), "100%".into(),
                   "1.000".into(), fmt3(s), fmt3(g)]);

    let variants: Vec<(String, Granularity, Method)> = {
        let mut v = vec![
            ("absmax/block".to_string(), Granularity::Block(128), Method::AbsMax),
            ("absmax/channel".to_string(), Granularity::PerChannel, Method::AbsMax),
        ];
        for (obj, label) in [(Objective::NegMse, "mse"),
                             (Objective::SignRate, "sign"),
                             (Objective::CosSim, "cos")] {
            v.push((
                format!("{label}/block [0.8,1.25]"),
                Granularity::Block(128),
                Method::Search { objective: obj, range: PAPER_RANGES[1] },
            ));
        }
        v
    };
    let mut daq_sign_params = None;
    for (label, gran, method) in variants {
        let keep = matches!(&method,
            Method::Search { objective: Objective::SignRate, .. });
        let out = measure(&tel, &format!("3. quantize {label}"), || {
            lab.quantize(gran, method.clone())
        })?;
        let (s, g) = measure(&tel, &format!("4. eval {label}"), || {
            lab.rubric(&out.params)
        })?;
        let a = out.agg.as_ref().unwrap();
        table.row(vec![label, fmt_l2(a.delta_l2()), fmt_pct(a.sign_rate()),
                       fmt3(a.cos_sim()), fmt3(s), fmt3(g)]);
        if keep {
            daq_sign_params = Some(out.params);
        }
    }
    println!("\n{}", table.render());

    // ---- 5. serving (PJRT runs the AOT full-sequence graph, so the
    //         reforward loop serves here; `daq serve` native uses the
    //         continuous-batching incremental scheduler) ----
    let params = daq_sign_params.expect("daq-sign variant ran");
    let rep = measure(&tel, "5. serve 32 requests", || {
        let fwd = PjrtForward { rt, params: &params, batch: rt.manifest.serve_batch };
        serve_reforward(&fwd, &gen_requests(32, 42), 8, params_bytes(&params))
    })?;
    println!(
        "serving: {:.1} tok/s | step latency {} | style adherence {:.1}%",
        rep.tokens_per_sec,
        rep.step_latency.summary(),
        100.0 * rep.style_adherence
    );

    println!("\n{}", tel.snapshot().render());
    println!("END-TO-END OK");
    Ok(())
}
