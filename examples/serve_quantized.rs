//! Serve the DAQ-quantized model with the FP8 params resident end-to-end:
//! continuous batching + incremental (KV-cached) greedy decoding through
//! the fused dequant-matmul — Python is not involved and the weights'
//! f32 image never materializes.
//!
//! Requires `make artifacts`. Run:
//! `cargo run --release --example serve_quantized`

use daq::coordinator::Method;
use daq::eval::decode::Decoder;
use daq::eval::QuantizedParams;
use daq::experiments::Lab;
use daq::quant::Granularity;
use daq::search::Objective;
use daq::serve::{gen_requests, serve, ServeConfig};

fn main() -> anyhow::Result<()> {
    let lab = Lab::open("artifacts", false)?;

    // Quantize with DAQ-sign, then serve the quantized model.
    let out = lab.quantize(
        Granularity::Block(128),
        Method::Search { objective: Objective::SignRate, range: (0.8, 1.25) },
    )?;
    let agg = out.agg.as_ref().unwrap();
    println!(
        "quantized {} layers in {:.2}s (SignRate {:.1}%, CosSim {:.3})\n",
        out.layers.len(),
        out.total_secs,
        100.0 * agg.sign_rate(),
        agg.cos_sim()
    );

    // Keep the FP8 codes+scales resident and serve through the
    // continuous-batching incremental decoder — the weights' f32 image
    // never materializes beyond one row of dequant scratch.
    let qp = QuantizedParams::from_pipeline(&out.params, &out.quantized);
    println!(
        "resident params: {:.2} MiB quantized vs {:.2} MiB f32",
        qp.resident_param_bytes() as f64 / (1 << 20) as f64,
        qp.f32_param_bytes() as f64 / (1 << 20) as f64,
    );
    let dec = Decoder::new(&qp, lab.cfg);
    let reqs = gen_requests(32, 42);
    let rep = serve(&dec, &reqs, &ServeConfig { slots: 8, new_tokens: 8 })?;

    println!(
        "served {} requests over {} slots, {} new tokens each",
        rep.requests, rep.slots, rep.new_tokens_per_request
    );
    println!("throughput: {:.1} tok/s", rep.tokens_per_sec);
    println!("request latency: {}", rep.request_latency.summary());
    println!(
        "style adherence of generated signatures: {:.1}%",
        100.0 * rep.style_adherence
    );
    println!("\nsample completions (first 3):");
    for (req, gen) in reqs.iter().zip(&rep.completions).take(3) {
        println!("  prompt {:?} -> {:?}", &req.prompt[1..6], gen);
    }
    Ok(())
}
