//! Serve the DAQ-quantized model: batched greedy decoding through the
//! AOT-compiled forward graph on PJRT — Python is not involved.
//!
//! Requires `make artifacts`. Run:
//! `cargo run --release --example serve_quantized`

use daq::coordinator::Method;
use daq::eval::PjrtForward;
use daq::experiments::Lab;
use daq::quant::Granularity;
use daq::search::Objective;
use daq::serve::{gen_requests, serve};

fn main() -> anyhow::Result<()> {
    let lab = Lab::open("artifacts", true)?;
    let rt = lab.rt.as_ref().expect("PJRT runtime");
    println!("PJRT platform: {}", rt.platform());

    // Quantize with DAQ-sign, then serve the quantized model.
    let out = lab.quantize(
        Granularity::Block(128),
        Method::Search { objective: Objective::SignRate, range: (0.8, 1.25) },
    )?;
    let agg = out.agg.as_ref().unwrap();
    println!(
        "quantized {} layers in {:.2}s (SignRate {:.1}%, CosSim {:.3})\n",
        out.layers.len(),
        out.total_secs,
        100.0 * agg.sign_rate(),
        agg.cos_sim()
    );

    let fwd = PjrtForward {
        rt,
        params: &out.params,
        batch: rt.manifest.serve_batch,
    };
    let reqs = gen_requests(32, 42);
    let rep = serve(&fwd, &reqs, 8)?;

    println!(
        "served {} requests ({} batches of {}), {} new tokens each",
        rep.requests, rep.batches, rt.manifest.serve_batch,
        rep.new_tokens_per_request
    );
    println!("throughput: {:.1} tok/s", rep.tokens_per_sec);
    println!("batch latency: {}", rep.batch_latency.summary());
    println!(
        "style adherence of generated signatures: {:.1}%",
        100.0 * rep.style_adherence
    );
    println!("\nsample completions (first 3):");
    for (req, gen) in reqs.iter().zip(&rep.completions).take(3) {
        println!("  prompt {:?} -> {:?}", &req.prompt[1..6], gen);
    }
    Ok(())
}
