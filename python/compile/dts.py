"""DTS — Delta Tensor Store: the checkpoint interchange format.

A tiny, dependency-free binary tensor container shared between the
build-time Python side (producer: train.py, aot.py) and the run-time Rust
side (consumer: rust/src/io/dts.rs). Format (all integers little-endian):

    magic   : 4 bytes  b"DTS1"
    version : u32      (currently 2; 1 = no checksum section)
    n_meta  : u32      number of metadata key/value pairs
    n_tensor: u32      number of tensors
    --- metadata entries, repeated n_meta times ---
    klen u16, key utf8, vlen u32, value utf8
    --- index entries, repeated n_tensor times ---
    nlen u16, name utf8, dtype u8, ndim u8, dims u64 * ndim,
    offset u64 (from start of payload), nbytes u64
    --- checksum section (version >= 2 only) ---
    crc32 u32 * n_tensor (zlib CRC-32 of each payload, index order)
    --- payload: raw tensor bytes, contiguous C-order ---

dtypes: 0 = f32, 1 = u8, 2 = i32, 3 = f64 (reserved), 4 = i64 (reserved).

The format is deliberately boring: no alignment games, no compression, no
string table. The Rust reader streams the index and then mmap-free
sequential-reads tensor payloads so multi-GB checkpoints never need to be
resident at once.
"""

from __future__ import annotations

import struct
import zlib
from dataclasses import dataclass

import numpy as np

MAGIC = b"DTS1"
VERSION = 2
VERSION_NO_CHECKSUM = 1

DTYPE_CODES = {
    np.dtype(np.float32): 0,
    np.dtype(np.uint8): 1,
    np.dtype(np.int32): 2,
}
CODE_DTYPES = {v: k for k, v in DTYPE_CODES.items()}


@dataclass
class TensorEntry:
    name: str
    dtype: np.dtype
    shape: tuple
    offset: int
    nbytes: int
    crc32: int | None = None  # None for v1 containers (no checksum section)


def write_dts(path: str, tensors: dict, meta: dict | None = None) -> None:
    """Write a dict of numpy arrays (and optional str->str metadata)."""
    meta = meta or {}
    index = []
    payload = bytearray()
    for name, arr in tensors.items():
        arr = np.ascontiguousarray(arr)
        if arr.dtype not in DTYPE_CODES:
            raise ValueError(f"unsupported dtype {arr.dtype} for tensor {name!r}")
        if len(name.encode()) > 0xFFFF:
            raise ValueError(f"tensor name of {len(name.encode())} bytes "
                             "exceeds the u16 length prefix")
        index.append((name, arr, len(payload)))
        payload.extend(arr.tobytes())

    with open(path, "wb") as f:
        f.write(MAGIC)
        f.write(struct.pack("<III", VERSION, len(meta), len(index)))
        for k, v in meta.items():
            kb, vb = k.encode(), str(v).encode()
            if len(kb) > 0xFFFF:
                raise ValueError(f"meta key of {len(kb)} bytes exceeds "
                                 "the u16 length prefix")
            if len(vb) > 0xFFFFFFFF:
                raise ValueError(f"meta value for {k!r} ({len(vb)} bytes) "
                                 "exceeds the u32 length prefix")
            f.write(struct.pack("<H", len(kb)))
            f.write(kb)
            f.write(struct.pack("<I", len(vb)))
            f.write(vb)
        for name, arr, off in index:
            nb = name.encode()
            f.write(struct.pack("<H", len(nb)))
            f.write(nb)
            f.write(struct.pack("<BB", DTYPE_CODES[arr.dtype], arr.ndim))
            for d in arr.shape:
                f.write(struct.pack("<Q", d))
            f.write(struct.pack("<QQ", off, arr.nbytes))
        for _, arr, _ in index:
            f.write(struct.pack("<I", zlib.crc32(arr.tobytes()) & 0xFFFFFFFF))
        f.write(bytes(payload))


SHARD_MANIFEST = "manifest.json"
SHARD_FORMAT = "daq-sharded-dts"
DEFAULT_SHARD_BUDGET = 256 << 20


def write_sharded_dts(dir_path: str, tensors: dict, meta: dict | None = None,
                      shard_budget_bytes: int = DEFAULT_SHARD_BUDGET) -> str:
    """Split tensors into DTS1 shard files by byte budget + manifest.json.

    Mirrors rust/src/io/shard.rs (`ShardWriter`): shards are complete
    standalone DTS containers named shard_NNNNN.dts; a shard rolls once its
    payload reaches the budget (so it may overshoot by one tensor). Returns
    the manifest path.
    """
    import json
    import os

    meta = meta or {}
    os.makedirs(dir_path, exist_ok=True)
    shards: list[dict] = []
    cur: dict = {}
    cur_bytes = 0

    def flush():
        nonlocal cur, cur_bytes
        if not cur:
            return
        fname = f"shard_{len(shards):05d}.dts"
        write_dts(os.path.join(dir_path, fname), cur,
                  {"shard_index": str(len(shards))})
        shards.append({"file": fname, "tensors": len(cur), "bytes": cur_bytes})
        cur, cur_bytes = {}, 0

    for name, arr in tensors.items():
        arr = np.ascontiguousarray(arr)
        cur[name] = arr
        cur_bytes += arr.nbytes
        if cur_bytes >= shard_budget_bytes:
            flush()
    flush()

    manifest = {
        "format": SHARD_FORMAT,
        "version": 1,
        "shard_budget_bytes": int(shard_budget_bytes),
        "meta": {k: str(v) for k, v in meta.items()},
        "shards": shards,
    }
    manifest_path = os.path.join(dir_path, SHARD_MANIFEST)
    with open(manifest_path, "w") as f:
        json.dump(manifest, f)
        f.write("\n")
    return manifest_path


def read_sharded_dts(path: str) -> tuple[dict, dict]:
    """Read a sharded store (manifest path or directory); returns
    (tensors, meta) like read_dts."""
    import json
    import os

    if os.path.isdir(path):
        path = os.path.join(path, SHARD_MANIFEST)
    with open(path) as f:
        manifest = json.load(f)
    if manifest.get("format") != SHARD_FORMAT:
        raise ValueError(f"{path}: not a sharded-store manifest")
    base = os.path.dirname(path)
    tensors: dict = {}
    for shard in manifest.get("shards", []):
        ts, _shard_meta = read_dts(os.path.join(base, shard["file"]))
        for name, arr in ts.items():
            if name in tensors:
                raise ValueError(f"{path}: tensor {name!r} in more than one shard")
            tensors[name] = arr
    return tensors, manifest.get("meta", {})


def read_dts(path: str) -> tuple[dict, dict]:
    """Read a DTS file; returns (tensors, meta)."""
    with open(path, "rb") as f:
        blob = f.read()
    if blob[:4] != MAGIC:
        raise ValueError(f"{path}: bad magic {blob[:4]!r}")
    version, n_meta, n_tensor = struct.unpack_from("<III", blob, 4)
    if version not in (VERSION, VERSION_NO_CHECKSUM):
        raise ValueError(f"{path}: unsupported version {version}")
    pos = 16
    meta = {}
    for _ in range(n_meta):
        (klen,) = struct.unpack_from("<H", blob, pos)
        pos += 2
        key = blob[pos : pos + klen].decode()
        pos += klen
        (vlen,) = struct.unpack_from("<I", blob, pos)
        pos += 4
        meta[key] = blob[pos : pos + vlen].decode()
        pos += vlen
    entries = []
    for _ in range(n_tensor):
        (nlen,) = struct.unpack_from("<H", blob, pos)
        pos += 2
        name = blob[pos : pos + nlen].decode()
        pos += nlen
        dtype_code, ndim = struct.unpack_from("<BB", blob, pos)
        pos += 2
        dims = struct.unpack_from("<" + "Q" * ndim, blob, pos)
        pos += 8 * ndim
        offset, nbytes = struct.unpack_from("<QQ", blob, pos)
        pos += 16
        entries.append(TensorEntry(name, CODE_DTYPES[dtype_code], dims, offset, nbytes))
    if version >= VERSION:
        # v2 checksum section: one u32 per tensor, in index order
        for e in entries:
            (e.crc32,) = struct.unpack_from("<I", blob, pos)
            pos += 4
    tensors = {}
    base = pos
    for e in entries:
        raw = blob[base + e.offset : base + e.offset + e.nbytes]
        if e.crc32 is not None:
            got = zlib.crc32(raw) & 0xFFFFFFFF
            if got != e.crc32:
                raise ValueError(
                    f"{path}: tensor {e.name!r}: checksum mismatch at payload "
                    f"offset {e.offset} ({e.nbytes} bytes): stored "
                    f"{e.crc32:#010x}, computed {got:#010x}")
        tensors[e.name] = np.frombuffer(raw, dtype=e.dtype).reshape(e.shape).copy()
    return tensors, meta
