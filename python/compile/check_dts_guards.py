#!/usr/bin/env python3
"""DTS length-guard checks — the executable coverage for the Python
writer's format guards (run by the CI `python` job; needs only numpy).

The DTS1 format length-prefixes names with u16 and meta values with u32
(see dts.py). The writer must refuse anything that would overflow a
prefix or silently truncate on the Rust reader side, and the reader must
refuse containers it cannot have written. Exit code 0 = all guards hold.
"""

from __future__ import annotations

import os
import sys
import tempfile

import numpy as np

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
import dts  # noqa: E402

FAILURES: list[str] = []


def check(label: str, fn) -> None:
    try:
        fn()
    except AssertionError as e:
        FAILURES.append(f"{label}: {e}")
    else:
        print(f"ok: {label}")


def expect_raises(label: str, exc, substr: str, fn) -> None:
    def run():
        try:
            fn()
        except exc as e:
            assert substr in str(e), f"raised {e!r}, wanted {substr!r} in message"
        else:
            raise AssertionError(f"expected {exc.__name__} ({substr!r}), got no error")

    check(label, run)


def main() -> int:
    tmp = tempfile.mkdtemp(prefix="daq_dts_guards_")
    p = os.path.join(tmp, "t.dts")
    w = np.zeros((2, 2), np.float32)

    # u16 name-length guard: a >64 KiB tensor name must be refused at
    # write time, not truncated into an unreadable index entry
    expect_raises(
        "tensor name over u16 prefix refused",
        ValueError,
        "u16 length prefix",
        lambda: dts.write_dts(p, {"n" * 0x10001: w}),
    )
    # ... and the largest representable name still round-trips
    def max_name_roundtrip():
        name = "n" * 0xFFFF
        dts.write_dts(p, {name: w})
        t2, _ = dts.read_dts(p)
        assert list(t2) == [name], "max-length name lost in round-trip"
        np.testing.assert_array_equal(t2[name], w)

    check("tensor name at exactly u16 max round-trips", max_name_roundtrip)

    expect_raises(
        "meta key over u16 prefix refused",
        ValueError,
        "u16 length prefix",
        lambda: dts.write_dts(p, {"w": w}, {"k" * 0x10001: "v"}),
    )

    expect_raises(
        "unsupported dtype refused",
        ValueError,
        "unsupported dtype",
        lambda: dts.write_dts(p, {"w": np.zeros(2, np.float64)}),
    )

    def bad_magic():
        bad = os.path.join(tmp, "bad.dts")
        with open(bad, "wb") as f:
            f.write(b"NOPE" + b"\x00" * 32)
        try:
            dts.read_dts(bad)
        except ValueError as e:
            assert "bad magic" in str(e)
        else:
            raise AssertionError("reader accepted a bad magic")

    check("reader refuses bad magic", bad_magic)

    def bad_version():
        import struct

        bad = os.path.join(tmp, "badver.dts")
        with open(bad, "wb") as f:
            f.write(dts.MAGIC)
            f.write(struct.pack("<III", 99, 0, 0))
        try:
            dts.read_dts(bad)
        except ValueError as e:
            assert "version" in str(e)
        else:
            raise AssertionError("reader accepted an unknown version")

    check("reader refuses unknown version", bad_version)

    # a large (but in-range) meta value round-trips through the u32 prefix
    def big_meta_roundtrip():
        big = "v" * 100_000
        dts.write_dts(p, {"w": w}, {"big": big})
        _, m2 = dts.read_dts(p)
        assert m2["big"] == big, "100 kB meta value corrupted"

    check("100kB meta value round-trips the u32 prefix", big_meta_roundtrip)

    if FAILURES:
        print(f"\n{len(FAILURES)} guard check(s) FAILED:", file=sys.stderr)
        for f in FAILURES:
            print(f"  - {f}", file=sys.stderr)
        return 1
    print("\nall DTS length guards hold")
    return 0


if __name__ == "__main__":
    sys.exit(main())
