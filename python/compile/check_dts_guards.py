#!/usr/bin/env python3
"""DTS length-guard checks — the executable coverage for the Python
writer's format guards (run by the CI `python` job; needs only numpy).

The DTS1 format length-prefixes names with u16 and meta values with u32
(see dts.py). The writer must refuse anything that would overflow a
prefix or silently truncate on the Rust reader side, and the reader must
refuse containers it cannot have written. Exit code 0 = all guards hold.
"""

from __future__ import annotations

import os
import sys
import tempfile

import numpy as np

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
import dts  # noqa: E402

FAILURES: list[str] = []


def check(label: str, fn) -> None:
    try:
        fn()
    except AssertionError as e:
        FAILURES.append(f"{label}: {e}")
    else:
        print(f"ok: {label}")


def expect_raises(label: str, exc, substr: str, fn) -> None:
    def run():
        try:
            fn()
        except exc as e:
            assert substr in str(e), f"raised {e!r}, wanted {substr!r} in message"
        else:
            raise AssertionError(f"expected {exc.__name__} ({substr!r}), got no error")

    check(label, run)


def main() -> int:
    tmp = tempfile.mkdtemp(prefix="daq_dts_guards_")
    p = os.path.join(tmp, "t.dts")
    w = np.zeros((2, 2), np.float32)

    # u16 name-length guard: a >64 KiB tensor name must be refused at
    # write time, not truncated into an unreadable index entry
    expect_raises(
        "tensor name over u16 prefix refused",
        ValueError,
        "u16 length prefix",
        lambda: dts.write_dts(p, {"n" * 0x10001: w}),
    )
    # ... and the largest representable name still round-trips
    def max_name_roundtrip():
        name = "n" * 0xFFFF
        dts.write_dts(p, {name: w})
        t2, _ = dts.read_dts(p)
        assert list(t2) == [name], "max-length name lost in round-trip"
        np.testing.assert_array_equal(t2[name], w)

    check("tensor name at exactly u16 max round-trips", max_name_roundtrip)

    expect_raises(
        "meta key over u16 prefix refused",
        ValueError,
        "u16 length prefix",
        lambda: dts.write_dts(p, {"w": w}, {"k" * 0x10001: "v"}),
    )

    expect_raises(
        "unsupported dtype refused",
        ValueError,
        "unsupported dtype",
        lambda: dts.write_dts(p, {"w": np.zeros(2, np.float64)}),
    )

    def bad_magic():
        bad = os.path.join(tmp, "bad.dts")
        with open(bad, "wb") as f:
            f.write(b"NOPE" + b"\x00" * 32)
        try:
            dts.read_dts(bad)
        except ValueError as e:
            assert "bad magic" in str(e)
        else:
            raise AssertionError("reader accepted a bad magic")

    check("reader refuses bad magic", bad_magic)

    def bad_version():
        import struct

        bad = os.path.join(tmp, "badver.dts")
        with open(bad, "wb") as f:
            f.write(dts.MAGIC)
            f.write(struct.pack("<III", 99, 0, 0))
        try:
            dts.read_dts(bad)
        except ValueError as e:
            assert "version" in str(e)
        else:
            raise AssertionError("reader accepted an unknown version")

    check("reader refuses unknown version", bad_version)

    # --- v2 checksum section ---

    def checksum_section_present():
        import struct
        import zlib

        cp = os.path.join(tmp, "crc.dts")
        arr = np.arange(12, dtype=np.float32).reshape(3, 4)
        dts.write_dts(cp, {"w": arr})
        blob = open(cp, "rb").read()
        version, _, n_tensor = struct.unpack_from("<III", blob, 4)
        assert version == 2, f"writer emitted version {version}, wanted 2"
        # the 4 bytes right before the payload are the tensor's CRC
        stored = struct.unpack_from("<I", blob, len(blob) - arr.nbytes - 4)[0]
        want = zlib.crc32(arr.tobytes()) & 0xFFFFFFFF
        assert stored == want, f"stored {stored:#010x}, wanted {want:#010x}"
        t2, _ = dts.read_dts(cp)
        np.testing.assert_array_equal(t2["w"], arr)

    check("v2 checksum section written and verified on read", checksum_section_present)

    def flipped_byte_rejected():
        cp = os.path.join(tmp, "flip.dts")
        dts.write_dts(cp, {"w": np.arange(8, dtype=np.float32)})
        blob = bytearray(open(cp, "rb").read())
        blob[-2] ^= 0x20  # payload byte of "w"
        with open(cp, "wb") as f:
            f.write(bytes(blob))
        try:
            dts.read_dts(cp)
        except ValueError as e:
            assert "checksum mismatch" in str(e), str(e)
            assert "'w'" in str(e), f"error must name the tensor: {e}"
        else:
            raise AssertionError("reader accepted a flipped payload byte")

    check("flipped payload byte rejected with tensor name", flipped_byte_rejected)

    def v1_store_reads_cleanly():
        import struct

        # hand-craft a v1 container (no checksum section) byte by byte
        arr = np.arange(4, dtype=np.float32)
        v1 = os.path.join(tmp, "v1.dts")
        nb = b"w"
        with open(v1, "wb") as f:
            f.write(dts.MAGIC)
            f.write(struct.pack("<III", 1, 0, 1))
            f.write(struct.pack("<H", len(nb)))
            f.write(nb)
            f.write(struct.pack("<BB", 0, 1))
            f.write(struct.pack("<Q", 4))
            f.write(struct.pack("<QQ", 0, arr.nbytes))
            f.write(arr.tobytes())
        t2, _ = dts.read_dts(v1)
        np.testing.assert_array_equal(t2["w"], arr)

    check("v1 container without checksums still reads", v1_store_reads_cleanly)

    def sharded_checksums_roundtrip():
        sd = os.path.join(tmp, "sharded")
        tensors = {f"t{i}": np.full((4,), i, np.float32) for i in range(3)}
        mp = dts.write_sharded_dts(sd, tensors, shard_budget_bytes=16)
        t2, _ = dts.read_sharded_dts(mp)
        assert sorted(t2) == sorted(tensors)
        # corrupt one shard's payload -> the sharded reader rejects it
        shard0 = os.path.join(sd, "shard_00000.dts")
        blob = bytearray(open(shard0, "rb").read())
        blob[-1] ^= 0x04
        with open(shard0, "wb") as f:
            f.write(bytes(blob))
        try:
            dts.read_sharded_dts(mp)
        except ValueError as e:
            assert "checksum mismatch" in str(e), str(e)
        else:
            raise AssertionError("sharded reader accepted a corrupt shard")

    check("sharded store emits + verifies checksums", sharded_checksums_roundtrip)

    # a large (but in-range) meta value round-trips through the u32 prefix
    def big_meta_roundtrip():
        big = "v" * 100_000
        dts.write_dts(p, {"w": w}, {"big": big})
        _, m2 = dts.read_dts(p)
        assert m2["big"] == big, "100 kB meta value corrupted"

    check("100kB meta value round-trips the u32 prefix", big_meta_roundtrip)

    if FAILURES:
        print(f"\n{len(FAILURES)} guard check(s) FAILED:", file=sys.stderr)
        for f in FAILURES:
            print(f"  - {f}", file=sys.stderr)
        return 1
    print("\nall DTS length guards hold")
    return 0


if __name__ == "__main__":
    sys.exit(main())
