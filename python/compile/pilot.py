"""Pilot experiment: validate the DAQ effect end-to-end in Python before
wiring the Rust pipeline. Trains base+SFT, quantizes the post model with
(a) AbsMax FP8, (b) MSE-searched scales, (c) DAQ sign, (d) DAQ cosine, and
prints the Style/General rubric for each — the Table 2/3/4/5 shape check.

Usage: cd python && python -m compile.pilot [--pre-steps N] [--sft-steps N]
"""

from __future__ import annotations

import argparse

import jax.numpy as jnp
import numpy as np

from . import corpus, model, train
from .kernels import ref


def quantize_model(post, base, granularity, metric, alphas_ranges=None, block=128):
    """Quantize all 2-D weights; returns (params, per-metric aggregates)."""
    out = dict(post)
    agg = {"agree": 0.0, "dot": 0.0, "nq": 0.0, "npost": 0.0, "sq": 0.0, "n": 0.0}
    for k in post:
        w = jnp.asarray(post[k])
        if w.ndim != 2 or k in ("embed", "pos"):
            continue
        wb = jnp.asarray(base[k])
        if granularity == "block":
            s0 = ref.expand_block_scale(ref.absmax_scale_block(w, block), w.shape, block)
        else:
            s0 = jnp.broadcast_to(ref.absmax_scale_channel(w), w.shape)
        if metric == "absmax":
            best_alpha = 1.0
        else:
            lo, hi = alphas_ranges
            cand = list(np.linspace(lo, hi, 5))
            stats = ref.sweep_ref(w, wb, s0, np.array(cand, np.float32))
            m = _metric_value(stats, metric)
            best = int(np.argmax(m))
            # fine stage around best
            delta = (hi - lo) / 4
            flo, fhi = max(lo, cand[best] - delta), min(hi, cand[best] + delta)
            fcand = list(np.linspace(flo, fhi, 10))
            fstats = ref.sweep_ref(w, wb, s0, np.array(fcand, np.float32))
            fm = _metric_value(fstats, metric)
            # include alpha=1 default as candidate (Algorithm 1 line 5-6)
            all_c = [1.0] + cand + fcand
            all_m = np.concatenate([
                _metric_value(ref.sweep_ref(w, wb, s0, np.array([1.0], np.float32)), metric),
                m, fm])
            best_alpha = float(all_c[int(np.argmax(all_m))])
        wq = ref.qdq_scaled(w, s0 * best_alpha)
        st = np.asarray(ref.delta_stats(w, wb, wq))
        for i, key in enumerate(["agree", "dot", "nq", "npost", "sq", "n"]):
            agg[key] += float(st[i])
        out[k] = np.asarray(wq)
    summary = {
        "sign_rate": agg["agree"] / agg["n"],
        "cos_sim": agg["dot"] / np.sqrt(max(agg["nq"] * agg["npost"], 1e-30)),
        "delta_l2": np.sqrt(agg["nq"]),
        "mse": agg["sq"] / agg["n"],
    }
    return out, summary


def _metric_value(stats, metric):
    stats = np.asarray(stats)
    m = ref.stats_to_metrics(jnp.asarray(stats))
    if metric == "sign":
        return np.asarray(m["sign_rate"])
    if metric == "cos":
        return np.asarray(m["cos_sim"])
    if metric == "mse":
        return -np.asarray(m["mse"])
    raise ValueError(metric)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--pre-steps", type=int, default=2000)
    ap.add_argument("--sft-steps", type=int, default=250)
    ap.add_argument("--sft-lr", type=float, default=1e-4)
    ap.add_argument("--out", default="/tmp/daq_pilot")
    args = ap.parse_args()
    import os
    os.makedirs(args.out, exist_ok=True)
    train.run(args.out, args.pre_steps, args.sft_steps, args.sft_lr)

    from .dts import read_dts
    base, _ = read_dts(f"{args.out}/ckpt_base.dts")
    post, _ = read_dts(f"{args.out}/ckpt_post.dts")
    st, _ = read_dts(f"{args.out}/eval_style.dts")
    ge, _ = read_dts(f"{args.out}/eval_general.dts")
    evalsets = {"style": (st["tokens"], st["mask"]),
                "general": (ge["tokens"], ge["mask"])}
    cfg = model.ModelConfig()

    def score(params):
        return model.rubric_scores({k: jnp.asarray(v) for k, v in params.items()},
                                   evalsets, cfg)

    rows = []
    for gran in ("block", "channel"):
        q, s = quantize_model(post, base, gran, "absmax")
        rows.append((f"AbsMax {gran}", s, score(q)))
    for metric in ("mse", "sign", "cos"):
        for gran in ("block", "channel"):
            for rng_ in ((0.5, 2.0), (0.8, 1.25), (0.9, 1.11)):
                q, s = quantize_model(post, base, gran, metric, rng_)
                rows.append((f"{metric} {gran} {rng_}", s, score(q)))

    print("\n=== PILOT RESULTS ===")
    print(f"{'config':34s} {'dL2':>9s} {'sign%':>7s} {'cos':>6s} {'Style':>6s} {'Genrl':>6s}")
    for name, s, sc in rows:
        print(f"{name:34s} {s['delta_l2']:9.2f} {100*s['sign_rate']:6.2f}% "
              f"{s['cos_sim']:6.3f} {sc['style']:6.3f} {sc['general']:6.3f}")


if __name__ == "__main__":
    main()
