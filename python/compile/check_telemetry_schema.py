#!/usr/bin/env python3
"""Schema gate for the telemetry layer's machine-readable outputs.

Validates the three artifacts `util::telemetry` emits against the
committed `telemetry_schema.json`:

- ``--metrics metrics.json`` — the registry snapshot written by
  ``daq quantize --stream --metrics-out`` (and at every shard-roll
  boundary). Required keys, counter non-negativity, bucket-vector
  lengths, and the per-histogram invariant ``sum(buckets) == count``.
- ``--events events.jsonl`` — the structured trace written by
  ``--trace-out``. Every line must parse, carry the required keys,
  have monotone non-decreasing ``ts_us``, a single run id, a known
  ``kind``, and spans must carry ``dur_us``.
- ``--exposition metrics.txt`` — a captured ``GET /metrics`` body
  (Prometheus text format 0.0.4): every sample belongs to a declared
  ``# TYPE`` family, histogram buckets are cumulative and end at
  ``+Inf`` with the ``_count`` value, counters are non-negative.

With no file arguments the script validates embedded fixtures (both
well-formed and deliberately broken ones) — the CI python job runs this
self-test so the gate itself is gated.

Exit code 0 = every requested artifact is well-formed.
"""

from __future__ import annotations

import argparse
import json
import math
import os
import re
import sys

SCHEMA_PATH = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                           "telemetry_schema.json")

METRIC_NAME = re.compile(r"^[a-zA-Z_:][a-zA-Z0-9_:]*$")


class SchemaError(Exception):
    pass


def fail(msg: str) -> None:
    raise SchemaError(msg)


def load_schema(path: str) -> dict:
    with open(path) as f:
        doc = json.load(f)
    if doc.get("schema") != "daq-telemetry":
        fail(f"{path}: not a daq-telemetry schema document")
    return doc


def is_num(v) -> bool:
    return isinstance(v, (int, float)) and not isinstance(v, bool)


def check_metrics(doc: dict, schema: dict) -> None:
    """Validate one metrics.json registry snapshot."""
    spec = schema["metrics"]
    if not isinstance(doc, dict):
        fail("metrics document is not an object")
    for key in spec["required"]:
        if key not in doc:
            fail(f"metrics document missing required key {key!r}")
    if not isinstance(doc["run_id"], str) or not doc["run_id"]:
        fail("run_id must be a non-empty string")

    bounds = doc["bucket_bounds"]
    if not isinstance(bounds, list) or len(bounds) != spec["bucket_bounds_len"]:
        fail(f"bucket_bounds must be a list of {spec['bucket_bounds_len']} bounds")
    if not all(is_num(b) and b > 0 for b in bounds):
        fail("bucket_bounds must be positive numbers")
    if any(b >= a for b, a in zip(bounds, bounds[1:])):
        fail("bucket_bounds must be strictly increasing")

    if not isinstance(doc["counters"], dict):
        fail("counters must be an object")
    for name, v in doc["counters"].items():
        if not is_num(v) or v < 0 or v != int(v):
            fail(f"counter {name!r} must be a non-negative integer, got {v!r}")

    if not isinstance(doc["gauges"], dict):
        fail("gauges must be an object")
    for name, v in doc["gauges"].items():
        if not is_num(v) or not math.isfinite(v):
            fail(f"gauge {name!r} must be a finite number, got {v!r}")

    hspec = spec["histogram"]
    if not isinstance(doc["histograms"], dict):
        fail("histograms must be an object")
    for name, h in doc["histograms"].items():
        if not isinstance(h, dict):
            fail(f"histogram {name!r} is not an object")
        for key in hspec["required"]:
            if key not in h:
                fail(f"histogram {name!r} missing {key!r}")
        if not is_num(h["count"]) or h["count"] < 0 or h["count"] != int(h["count"]):
            fail(f"histogram {name!r}: count must be a non-negative integer")
        if not is_num(h["sum"]) or not math.isfinite(h["sum"]):
            fail(f"histogram {name!r}: sum must be a finite number")
        buckets = h["buckets"]
        if not isinstance(buckets, list) or len(buckets) != hspec["buckets_len"]:
            fail(f"histogram {name!r}: buckets must be a list of "
                 f"{hspec['buckets_len']} counts (last is +Inf)")
        if not all(is_num(b) and b >= 0 and b == int(b) for b in buckets):
            fail(f"histogram {name!r}: bucket counts must be non-negative integers")
        if sum(buckets) != h["count"]:
            fail(f"histogram {name!r}: sum(buckets) == {sum(buckets)} "
                 f"!= count == {h['count']}")


def check_events(lines: list, schema: dict) -> int:
    """Validate a JSONL trace; returns the number of records checked."""
    spec = schema["events"]
    last_ts = -math.inf
    run_id = None
    n = 0
    for i, line in enumerate(lines):
        line = line.strip()
        if not line:
            continue
        try:
            doc = json.loads(line)
        except json.JSONDecodeError as e:
            fail(f"trace line {i}: unparseable JSON ({e})")
        if not isinstance(doc, dict):
            fail(f"trace line {i}: not an object")
        for key in spec["required"]:
            if key not in doc:
                fail(f"trace line {i}: missing required key {key!r}")
        ts = doc["ts_us"]
        if not is_num(ts) or ts < 0:
            fail(f"trace line {i}: ts_us must be a non-negative number")
        if ts < last_ts:
            fail(f"trace line {i}: ts_us went backwards ({ts} < {last_ts})")
        last_ts = ts
        if run_id is None:
            run_id = doc["run"]
        elif doc["run"] != run_id:
            fail(f"trace line {i}: run id changed mid-trace "
                 f"({doc['run']!r} != {run_id!r})")
        kind = doc["kind"]
        if kind not in spec["kinds"]:
            fail(f"trace line {i}: unknown kind {kind!r}")
        if kind == "span":
            for key in spec["span_required"]:
                if key not in doc:
                    fail(f"trace line {i}: span missing {key!r}")
            if not is_num(doc["dur_us"]) or doc["dur_us"] < 0:
                fail(f"trace line {i}: dur_us must be a non-negative number")
        n += 1
    return n


def check_exposition(text: str) -> int:
    """Validate a Prometheus text-format body; returns the sample count."""
    declared: dict[str, str] = {}
    samples = 0
    # per-histogram running state for cumulativity / +Inf checks
    hist_state: dict[str, dict] = {}
    for i, line in enumerate(text.splitlines()):
        if not line.strip():
            continue
        if line.startswith("# TYPE "):
            parts = line.split()
            if len(parts) != 4:
                fail(f"exposition line {i}: malformed TYPE line: {line!r}")
            _, _, name, mtype = parts
            if mtype not in ("counter", "gauge", "histogram"):
                fail(f"exposition line {i}: unknown metric type {mtype!r}")
            if not METRIC_NAME.match(name):
                fail(f"exposition line {i}: invalid metric name {name!r}")
            declared[name] = mtype
            if mtype == "histogram":
                hist_state[name] = {"last_cum": -1, "inf": None, "count": None}
            continue
        if line.startswith("#"):
            continue
        m = re.match(r"^([a-zA-Z_:][a-zA-Z0-9_:]*)(\{[^}]*\})?\s+(\S+)$", line)
        if m is None:
            fail(f"exposition line {i}: malformed sample: {line!r}")
        name, labels, raw = m.groups()
        try:
            value = float(raw)
        except ValueError:
            fail(f"exposition line {i}: non-numeric value {raw!r}")
        family, part = name, None
        for suffix in ("_total", "_bucket", "_sum", "_count"):
            if name.endswith(suffix):
                family, part = name[: -len(suffix)], suffix
                break
        if part == "_total":
            if declared.get(name) != "counter":
                fail(f"exposition line {i}: sample {name!r} has no "
                     f"counter TYPE declaration")
            if value < 0:
                fail(f"exposition line {i}: counter {name!r} is negative")
        elif part in ("_bucket", "_sum", "_count") and family in hist_state:
            st = hist_state[family]
            if part == "_bucket":
                if not labels or 'le="' not in labels:
                    fail(f"exposition line {i}: bucket without le label")
                if value < st["last_cum"]:
                    fail(f"exposition line {i}: histogram {family!r} "
                         f"buckets are not cumulative")
                st["last_cum"] = value
                if 'le="+Inf"' in labels:
                    st["inf"] = value
            elif part == "_count":
                st["count"] = value
        else:
            if declared.get(name) != "gauge":
                fail(f"exposition line {i}: sample {name!r} has no "
                     f"TYPE declaration")
        samples += 1
    for family, st in hist_state.items():
        if st["inf"] is None:
            fail(f"histogram {family!r} has no +Inf bucket")
        if st["count"] is not None and st["inf"] != st["count"]:
            fail(f"histogram {family!r}: +Inf bucket ({st['inf']}) "
                 f"!= _count ({st['count']})")
    if samples == 0:
        fail("exposition body contains no samples")
    return samples


# ---------------------------------------------------------------------
# embedded self-test fixtures (run when no file arguments are given)

GOOD_METRICS = {
    "run_id": "selftest-1",
    "bucket_bounds": [1e-6 * 4**i for i in range(16)],
    "counters": {"stream.retries": 2, "shard.rolls": 3},
    "gauges": {"serve.slot_occupancy": 4.0},
    "histograms": {
        "stream.read.seconds": {
            "count": 5,
            "sum": 0.012,
            "buckets": [0, 0, 1, 2, 2] + [0] * 12,
        }
    },
}

GOOD_EVENTS = [
    '{"ts_us": 10.0, "run": "r", "kind": "span", "name": "stream.read", "dur_us": 42.0}',
    '{"ts_us": 11.0, "run": "r", "kind": "event", "name": "stream.retry", "attempt": 1}',
    '{"ts_us": 11.0, "run": "r", "kind": "span", "name": "stream.write", "dur_us": 0.0}',
]

GOOD_EXPOSITION = """\
# TYPE daq_stream_retries_total counter
daq_stream_retries_total 2
# TYPE daq_serve_slot_occupancy gauge
daq_serve_slot_occupancy 4
# TYPE daq_stream_read_seconds histogram
daq_stream_read_seconds_bucket{le="1e-6"} 0
daq_stream_read_seconds_bucket{le="4e-6"} 1
daq_stream_read_seconds_bucket{le="+Inf"} 5
daq_stream_read_seconds_sum 0.012
daq_stream_read_seconds_count 5
"""


def selftest(schema: dict) -> None:
    check_metrics(GOOD_METRICS, schema)
    assert check_events(GOOD_EVENTS, schema) == 3
    assert check_exposition(GOOD_EXPOSITION) == 7

    def must_fail(what: str, fn) -> None:
        try:
            fn()
        except SchemaError:
            return
        sys.exit(f"selftest: {what} was accepted but must be rejected")

    bad_counter = json.loads(json.dumps(GOOD_METRICS))
    bad_counter["counters"]["stream.retries"] = -1
    must_fail("negative counter", lambda: check_metrics(bad_counter, schema))

    bad_hist = json.loads(json.dumps(GOOD_METRICS))
    bad_hist["histograms"]["stream.read.seconds"]["count"] = 99
    must_fail("buckets/count mismatch", lambda: check_metrics(bad_hist, schema))

    missing_key = {k: v for k, v in GOOD_METRICS.items() if k != "run_id"}
    must_fail("missing run_id", lambda: check_metrics(missing_key, schema))

    non_monotonic = [GOOD_EVENTS[1], GOOD_EVENTS[0]]
    must_fail("non-monotonic ts_us", lambda: check_events(non_monotonic, schema))

    spanless = ['{"ts_us": 1, "run": "r", "kind": "span", "name": "x"}']
    must_fail("span without dur_us", lambda: check_events(spanless, schema))

    undeclared = "daq_mystery_total 3\n"
    must_fail("undeclared sample", lambda: check_exposition(undeclared))

    shrinking = (
        "# TYPE daq_h histogram\n"
        'daq_h_bucket{le="1e-6"} 5\n'
        'daq_h_bucket{le="+Inf"} 3\n'
        "daq_h_sum 1\ndaq_h_count 3\n"
    )
    must_fail("non-cumulative buckets", lambda: check_exposition(shrinking))

    print("ok: telemetry schema selftest passed (3 artifacts, 7 rejections)")


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--metrics", help="metrics.json snapshot to validate")
    ap.add_argument("--events", help="events.jsonl trace to validate")
    ap.add_argument("--exposition", help="captured GET /metrics body to validate")
    ap.add_argument("--schema", default=SCHEMA_PATH,
                    help=f"schema document (default {SCHEMA_PATH})")
    args = ap.parse_args()

    try:
        schema = load_schema(args.schema)
        if not (args.metrics or args.events or args.exposition):
            selftest(schema)
            return 0
        if args.metrics:
            with open(args.metrics) as f:
                doc = json.load(f)
            check_metrics(doc, schema)
            print(f"ok: {args.metrics} is a well-formed registry snapshot")
        if args.events:
            with open(args.events) as f:
                n = check_events(f.readlines(), schema)
            print(f"ok: {args.events} is a well-formed trace ({n} records)")
        if args.exposition:
            with open(args.exposition) as f:
                n = check_exposition(f.read())
            print(f"ok: {args.exposition} is well-formed exposition text "
                  f"({n} samples)")
    except (OSError, json.JSONDecodeError) as e:
        sys.exit(f"error: {e}")
    except SchemaError as e:
        sys.exit(f"error: {e}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
