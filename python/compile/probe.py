"""Probe: proximal-SFT sweep — find the (lambda, lr, steps) where the SFT
delta is minimal-norm (FP8-fragile) but the style is still learned, and
report per-position style accuracy + AbsMax-FP8 damage + DAQ recovery.

Usage: cd python && PROX="3e-4,600,1e-2 3e-4,600,3e-2" python -m compile.probe
"""

from __future__ import annotations

import os

import jax.numpy as jnp
import numpy as np

from . import corpus, dts, model, train
from .kernels import ref
from .pilot import quantize_model
from .tune import BASE_CACHE


def per_position_style_acc(params, cfg, n=256):
    rng = np.random.default_rng(1234)
    tok, _ = corpus.style_eval_set(rng, n)
    logits = model.forward({k: jnp.asarray(v) for k, v in params.items()},
                           jnp.asarray(tok), cfg)
    pred = np.asarray(jnp.argmax(logits[:, :-1], axis=-1))
    tgt = tok[:, 1:]
    sep = 1 + corpus.PROMPT_LEN
    accs = []
    for i in range(corpus.STYLE_SIG_LEN):
        p = sep + i  # prediction position for sig token i+1
        accs.append(float((pred[:, p - 1 + 1 - 1] == tgt[:, p - 1]).mean())
                    if False else float((pred[:, p] == tgt[:, p]).mean()))
    return accs


def main():
    cfg = model.ModelConfig()
    base, _ = dts.read_dts(BASE_CACHE)
    erng = np.random.default_rng(1000)
    st = corpus.style_eval_set(erng, 384)
    ge = corpus.general_eval_set(erng, 384)
    evalsets = {"style": st, "general": ge}

    def score(p):
        return model.rubric_scores({k: jnp.asarray(v) for k, v in p.items()},
                                   evalsets, cfg)

    prox_ref = {k: jnp.asarray(v) for k, v in base.items()}
    configs = os.environ.get("PROX", "3e-4,600,1e-2").split()
    for spec in configs:
        lr, steps, lam = spec.split(",")
        lr, steps, lam = float(lr), int(steps), float(lam)
        params = {k: jnp.asarray(v) for k, v in base.items()}
        params, losses = train.train_phase(
            params, cfg, corpus.sft_batch, steps, 64, lr, 20, seed=2,
            label=f"sft[lr={lr:g},lam={lam:g}]", completion_only=True,
            prox_ref=prox_ref, prox_lambda=lam, log_every=300)
        post = train.params_to_numpy(params)
        dl2, wl2 = train.delta_summary(base, post)
        sp = score(post)
        pp = per_position_style_acc(post, cfg)
        print(f"PROX lr={lr:g} steps={steps} lam={lam:g}: "
              f"style={sp['style']:.3f} general={sp['general']:.3f} "
              f"dRatio={dl2/wl2:.3%} per-pos={['%.2f' % a for a in pp]}",
              flush=True)
        if sp["style"] < 1.0:
            print("  -> style too low", flush=True)
            continue
        q, s = quantize_model(post, base, "block", "absmax")
        sq = score(q)
        print(f"  AbsMax block: style={sq['style']:.3f} "
              f"general={sq['general']:.3f} sign={100*s['sign_rate']:.1f}% "
              f"cos={s['cos_sim']:.3f}", flush=True)
        damage = sp["style"] - sq["style"]
        if damage > 0.15:
            for metric in ("sign", "cos", "mse"):
                q2, s2 = quantize_model(post, base, "block", metric, (0.8, 1.25))
                sq2 = score(q2)
                print(f"  {metric:4s} block [0.8,1.25]: style={sq2['style']:.3f} "
                      f"general={sq2['general']:.3f} "
                      f"sign={100*s2['sign_rate']:.1f}%", flush=True)


if __name__ == "__main__":
    main()
