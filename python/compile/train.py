"""Build-time trainer: produces the (W_base, W_post) checkpoint pair.

This is the substrate the paper takes for granted (DeepSeek-V3 + an SFT run
on stylized dialogues). We pretrain a small decoder-only LM on the general
corpus (→ ckpt_base.dts), then SFT it on the styled corpus with a low
learning rate and few steps (→ ckpt_post.dts) so the style knowledge lives
in small-magnitude deltas — the regime DAQ targets (paper §1, §5).

Also emits:
  eval_style.dts / eval_general.dts — held-out rubric eval sets
  calib.dts                         — per-channel |activation| means for
                                      SmoothQuant / AWQ baselines
All outputs are deterministic given the seeds.

Usage:  cd python && python -m compile.train --out ../artifacts
"""

from __future__ import annotations

import argparse
import json
import time
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from . import corpus, dts, model


# ---------------------------------------------------------------------------
# Manual Adam (optax is not available in the offline image)
# ---------------------------------------------------------------------------

def adam_init(params):
    zeros = {k: jnp.zeros_like(v) for k, v in params.items()}
    return {"m": zeros, "v": {k: jnp.zeros_like(v) for k, v in params.items()},
            "t": jnp.zeros((), jnp.int32)}


def adam_update(params, grads, state, lr, b1=0.9, b2=0.999, eps=1e-8):
    t = state["t"] + 1
    m = {k: b1 * state["m"][k] + (1 - b1) * grads[k] for k in params}
    v = {k: b2 * state["v"][k] + (1 - b2) * jnp.square(grads[k]) for k in params}
    bc1 = 1 - b1 ** t.astype(jnp.float32)
    bc2 = 1 - b2 ** t.astype(jnp.float32)
    new = {k: params[k] - lr * (m[k] / bc1) / (jnp.sqrt(v[k] / bc2) + eps)
           for k in params}
    return new, {"m": m, "v": v, "t": t}


@partial(jax.jit, static_argnums=(3,))
def _train_step(params, opt, batch, cfg, lr, loss_mask=None, prox_ref=None,
                prox_lambda=0.0):
    def objective(p):
        loss = model.loss_fn(p, batch, cfg, loss_mask)
        if prox_ref is not None:
            # proximal SFT: penalize distance to the base checkpoint so the
            # optimizer finds the minimal-norm delta that achieves the SFT
            # behaviour (the paper's "small yet semantically critical"
            # regime; standard KL/L2-regularized fine-tuning practice)
            prox = sum(jnp.sum(jnp.square(p[k] - prox_ref[k])) for k in prox_ref)
            loss = loss + prox_lambda * prox
        return loss

    loss, grads = jax.value_and_grad(objective)(params)
    params, opt = adam_update(params, grads, opt, lr)
    return params, opt, loss


def train_phase(params, cfg, sampler, steps, batch_size, lr_peak, warmup,
                seed, label, log_every=200, completion_only=False,
                prox_ref=None, prox_lambda=0.0):
    """One optimization phase (pretrain or SFT) with linear warmup + cosine
    decay. `completion_only` masks the loss to positions at/after SEP —
    standard SFT practice; it also concentrates the delta in the response
    behaviour, matching the paper's setting. `prox_ref`/`prox_lambda` add
    an L2-to-base proximal term (see _train_step)."""
    rng = np.random.default_rng(seed)
    opt = adam_init(params)
    losses = []
    t0 = time.time()
    mask = None
    if completion_only:
        m = np.zeros((batch_size, cfg.seq_len), np.float32)
        m[:, 1 + corpus.PROMPT_LEN:] = 1.0  # SEP onward
        mask = jnp.asarray(m)
    for step in range(steps):
        if step < warmup:
            lr = lr_peak * (step + 1) / warmup
        else:
            prog = (step - warmup) / max(steps - warmup, 1)
            lr = lr_peak * 0.5 * (1 + np.cos(np.pi * prog))
        batch = jnp.asarray(sampler(rng, batch_size))
        params, opt, loss = _train_step(params, opt, batch, cfg, jnp.float32(lr),
                                        mask, prox_ref, prox_lambda)
        losses.append(float(loss))
        if step % log_every == 0 or step == steps - 1:
            print(f"[{label}] step {step:5d} loss {float(loss):.4f} "
                  f"lr {lr:.2e} ({time.time()-t0:.1f}s)", flush=True)
    return params, losses


# ---------------------------------------------------------------------------
# Checkpoint production
# ---------------------------------------------------------------------------

def params_to_numpy(params):
    return {k: np.asarray(v, np.float32) for k, v in params.items()}


def delta_summary(base, post):
    """Global ‖ΔW‖ vs ‖W‖ over quantizable tensors — sanity check that we
    are in the paper's small-delta regime."""
    tot_d, tot_w = 0.0, 0.0
    for k in base:
        if base[k].ndim != 2:
            continue
        d = post[k] - base[k]
        tot_d += float(np.sum(d * d))
        tot_w += float(np.sum(base[k] * base[k]))
    return float(np.sqrt(tot_d)), float(np.sqrt(tot_w))


def run(out_dir: str, pre_steps: int, sft_steps: int, sft_lr: float,
        seed: int = 0, eval_n: int = 512) -> dict:
    cfg = model.ModelConfig()
    key = jax.random.PRNGKey(seed)
    params = model.init_params(cfg, key)
    n_params = cfg.param_count(params)
    print(f"model: {n_params/1e6:.2f}M params "
          f"(d={cfg.d_model} L={cfg.n_layer} h={cfg.n_head} ff={cfg.d_ff})")

    # --- pretrain (base model): pattern mixture incl. variant-0 style ---
    params, pre_losses = train_phase(
        params, cfg, corpus.pretrain_batch, pre_steps, 64, 1.5e-3, 100,
        seed=seed + 1, label="pretrain")
    base = params_to_numpy(params)

    # --- SFT (post-trained model): low LR, completion-only loss => small,
    # behaviourally-focused deltas (the paper's regime) ---
    params, sft_losses = train_phase(
        params, cfg, corpus.sft_batch, sft_steps, 64, sft_lr, 20,
        seed=seed + 2, label="sft", completion_only=True)
    post = params_to_numpy(params)

    dl2, wl2 = delta_summary(base, post)
    print(f"delta check: ||dW||={dl2:.4f}  ||W||={wl2:.4f}  ratio={dl2/wl2:.4%}")

    # --- eval sets (held-out seeds) ---
    erng = np.random.default_rng(seed + 1000)
    style_tok, style_mask = corpus.style_eval_set(erng, eval_n)
    gen_tok, gen_mask = corpus.general_eval_set(erng, eval_n)
    evalsets = {"style": (style_tok, style_mask), "general": (gen_tok, gen_mask)}

    scores_base = model.rubric_scores({k: jnp.asarray(v) for k, v in base.items()},
                                      evalsets, cfg)
    scores_post = model.rubric_scores({k: jnp.asarray(v) for k, v in post.items()},
                                      evalsets, cfg)
    print(f"base  scores: {scores_base}")
    print(f"post  scores: {scores_post}")

    # --- calibration activations (for SmoothQuant / AWQ) ---
    crng = np.random.default_rng(seed + 2000)
    calib_tok = np.concatenate([corpus.general_batch(crng, 128),
                                corpus.styled_batch(crng, 128)])
    _, acts = model.forward(
        {k: jnp.asarray(v) for k, v in post.items()},
        jnp.asarray(calib_tok), cfg, collect_acts=True)
    calib = {k: np.asarray(v, np.float32) for k, v in acts.items()}

    # --- write everything ---
    meta_common = {
        "d_model": cfg.d_model, "n_layer": cfg.n_layer, "n_head": cfg.n_head,
        "d_ff": cfg.d_ff, "vocab": cfg.vocab, "seq_len": cfg.seq_len,
        "n_params": n_params,
    }
    dts.write_dts(f"{out_dir}/ckpt_base.dts", base,
                  {**meta_common, "kind": "base",
                   "style": f"{scores_base['style']:.4f}",
                   "general": f"{scores_base['general']:.4f}"})
    dts.write_dts(f"{out_dir}/ckpt_post.dts", post,
                  {**meta_common, "kind": "post",
                   "style": f"{scores_post['style']:.4f}",
                   "general": f"{scores_post['general']:.4f}"})
    dts.write_dts(f"{out_dir}/eval_style.dts",
                  {"tokens": style_tok, "mask": style_mask}, {"kind": "eval_style"})
    dts.write_dts(f"{out_dir}/eval_general.dts",
                  {"tokens": gen_tok, "mask": gen_mask}, {"kind": "eval_general"})
    dts.write_dts(f"{out_dir}/calib.dts", calib, {"kind": "calib"})

    summary = {
        "n_params": n_params,
        "delta_l2": dl2, "weight_l2": wl2,
        "scores_base": scores_base, "scores_post": scores_post,
        "pretrain_final_loss": pre_losses[-1], "sft_final_loss": sft_losses[-1],
    }
    with open(f"{out_dir}/train_summary.json", "w") as f:
        json.dump(summary, f, indent=2)
    return summary


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--pre-steps", type=int, default=3000)
    ap.add_argument("--sft-steps", type=int, default=250)
    ap.add_argument("--sft-lr", type=float, default=1e-4)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    import os
    os.makedirs(args.out, exist_ok=True)
    run(args.out, args.pre_steps, args.sft_steps, args.sft_lr, args.seed)


if __name__ == "__main__":
    main()
