"""Produce the full artifacts/ bundle reusing the cached pretrained base
(/tmp/daq_base.dts from compile.tune) to avoid re-pretraining: runs SFT at
the chosen hyperparameters, writes checkpoints + eval sets + calib, then
invokes the AOT lowering.

Usage: cd python && python -m compile.finalize --out ../artifacts \
           --sft-steps 600 --sft-lr 3e-5
"""

from __future__ import annotations

import argparse
import json
import os

import jax.numpy as jnp
import numpy as np

from . import corpus, dts, model, train
from .tune import BASE_CACHE


def main():  # finalize
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--sft-steps", type=int, default=600)
    ap.add_argument("--sft-lr", type=float, default=3e-4)
    ap.add_argument("--prox-lambda", type=float, default=1.0)
    ap.add_argument("--eval-n", type=int, default=512)
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    cfg = model.ModelConfig()
    base, _ = dts.read_dts(BASE_CACHE)
    print(f"loaded cached base from {BASE_CACHE}")

    params = {k: jnp.asarray(v) for k, v in base.items()}
    params, sft_losses = train.train_phase(
        params, cfg, corpus.sft_batch, args.sft_steps, 64, args.sft_lr, 20,
        seed=2, label="sft", completion_only=True,
        prox_ref={k: jnp.asarray(v) for k, v in base.items()},
        prox_lambda=args.prox_lambda)
    post = train.params_to_numpy(params)

    dl2, wl2 = train.delta_summary(base, post)
    print(f"delta: ||dW||={dl2:.4f} ||W||={wl2:.4f} ratio={dl2/wl2:.3%}")

    erng = np.random.default_rng(1000)
    style_tok, style_mask = corpus.style_eval_set(erng, args.eval_n)
    gen_tok, gen_mask = corpus.general_eval_set(erng, args.eval_n)
    evalsets = {"style": (style_tok, style_mask), "general": (gen_tok, gen_mask)}

    sb = model.rubric_scores({k: jnp.asarray(v) for k, v in base.items()}, evalsets, cfg)
    sp = model.rubric_scores({k: jnp.asarray(v) for k, v in post.items()}, evalsets, cfg)
    print(f"base  scores: {sb}")
    print(f"post  scores: {sp}")

    crng = np.random.default_rng(2000)
    calib_tok = np.concatenate([corpus.pretrain_batch(crng, 128),
                                corpus.sft_batch(crng, 128)])
    _, acts = model.forward({k: jnp.asarray(v) for k, v in post.items()},
                            jnp.asarray(calib_tok), cfg, collect_acts=True)
    calib = {k: np.asarray(v, np.float32) for k, v in acts.items()}

    n_params = cfg.param_count({k: jnp.asarray(v) for k, v in post.items()})
    meta_common = {
        "d_model": cfg.d_model, "n_layer": cfg.n_layer, "n_head": cfg.n_head,
        "d_ff": cfg.d_ff, "vocab": cfg.vocab, "seq_len": cfg.seq_len,
        "n_params": n_params,
    }
    dts.write_dts(f"{args.out}/ckpt_base.dts", base,
                  {**meta_common, "kind": "base",
                   "style": f"{sb['style']:.4f}", "general": f"{sb['general']:.4f}"})
    dts.write_dts(f"{args.out}/ckpt_post.dts", post,
                  {**meta_common, "kind": "post",
                   "style": f"{sp['style']:.4f}", "general": f"{sp['general']:.4f}"})
    dts.write_dts(f"{args.out}/eval_style.dts",
                  {"tokens": style_tok, "mask": style_mask}, {"kind": "eval_style"})
    dts.write_dts(f"{args.out}/eval_general.dts",
                  {"tokens": gen_tok, "mask": gen_mask}, {"kind": "eval_general"})
    dts.write_dts(f"{args.out}/calib.dts", calib, {"kind": "calib"})
    with open(f"{args.out}/train_summary.json", "w") as f:
        json.dump({"n_params": n_params, "delta_l2": dl2, "weight_l2": wl2,
                   "scores_base": sb, "scores_post": sp,
                   "sft_steps": args.sft_steps, "sft_lr": args.sft_lr,
                   "prox_lambda": args.prox_lambda,
                   "sft_final_loss": sft_losses[-1]}, f, indent=2)
    print("checkpoints + eval sets written")


if __name__ == "__main__":
    main()
