"""L2: the transformer compute graph (JAX, build-time only).

A GPT-style decoder-only LM used three ways:
  1. train.py optimizes it to produce the (base, post-trained) checkpoint
     pair the DAQ experiments need;
  2. aot.py lowers `forward` to HLO text so the Rust runtime can evaluate
     and serve checkpoints via PJRT with Python off the request path;
  3. the pytest suite uses it as the shape/numerics oracle.

Parameters live in a flat {name: array} dict whose names match the tensor
names in the DTS checkpoints (and therefore the names the Rust coordinator
schedules). Quantizable tensors (2-D matmul weights) are listed by
`quantizable_names`.
"""

from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from . import corpus


# ---------------------------------------------------------------------------
# Configuration
# ---------------------------------------------------------------------------

class ModelConfig:
    """Transformer hyperparameters."""

    def __init__(self, vocab=corpus.VOCAB, d_model=128, n_layer=2, n_head=4,
                 d_ff=512, seq_len=corpus.SEQ_LEN):
        self.vocab = vocab
        self.d_model = d_model
        self.n_layer = n_layer
        self.n_head = n_head
        self.d_ff = d_ff
        self.seq_len = seq_len
        assert d_model % n_head == 0

    @property
    def d_head(self):
        return self.d_model // self.n_head

    def param_count(self, params=None):
        if params is None:
            params = init_params(self, jax.random.PRNGKey(0))
        return sum(int(np.prod(v.shape)) for v in params.values())


def quantizable_names(cfg: ModelConfig) -> list:
    """The 2-D linear weights DAQ quantizes (the paper quantizes matmul
    weights; embeddings and norms stay high-precision)."""
    names = []
    for l in range(cfg.n_layer):
        names += [f"l{l}.wq", f"l{l}.wk", f"l{l}.wv", f"l{l}.wo",
                  f"l{l}.w1", f"l{l}.w2"]
    names.append("head")
    return names


# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------

def init_params(cfg: ModelConfig, key) -> dict:
    ks = jax.random.split(key, 2 + 6 * cfg.n_layer)
    it = iter(ks)

    def dense(key, fan_in, fan_out):
        std = 1.0 / math.sqrt(fan_in)
        return (jax.random.normal(key, (fan_in, fan_out)) * std).astype(jnp.float32)

    p = {
        "embed": (jax.random.normal(next(it), (cfg.vocab, cfg.d_model)) * 0.02
                  ).astype(jnp.float32),
        "pos": (jax.random.normal(next(it), (cfg.seq_len, cfg.d_model)) * 0.02
                ).astype(jnp.float32),
    }
    for l in range(cfg.n_layer):
        p[f"l{l}.ln1.g"] = jnp.ones((cfg.d_model,), jnp.float32)
        p[f"l{l}.ln1.b"] = jnp.zeros((cfg.d_model,), jnp.float32)
        p[f"l{l}.wq"] = dense(next(it), cfg.d_model, cfg.d_model)
        p[f"l{l}.wk"] = dense(next(it), cfg.d_model, cfg.d_model)
        p[f"l{l}.wv"] = dense(next(it), cfg.d_model, cfg.d_model)
        p[f"l{l}.wo"] = dense(next(it), cfg.d_model, cfg.d_model)
        p[f"l{l}.ln2.g"] = jnp.ones((cfg.d_model,), jnp.float32)
        p[f"l{l}.ln2.b"] = jnp.zeros((cfg.d_model,), jnp.float32)
        p[f"l{l}.w1"] = dense(next(it), cfg.d_model, cfg.d_ff)
        p[f"l{l}.w2"] = dense(next(it), cfg.d_ff, cfg.d_model)
    p["lnf.g"] = jnp.ones((cfg.d_model,), jnp.float32)
    p["lnf.b"] = jnp.zeros((cfg.d_model,), jnp.float32)
    # untied head so its delta is independently quantized
    p["head"] = dense(jax.random.PRNGKey(1234), cfg.d_model, cfg.vocab)
    return p


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------

def _layernorm(x, g, b, eps=1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * g + b


def _attention(x, wq, wk, wv, wo, n_head):
    B, T, D = x.shape
    dh = D // n_head

    def split(h):
        return h.reshape(B, T, n_head, dh).transpose(0, 2, 1, 3)

    q, k, v = split(x @ wq), split(x @ wk), split(x @ wv)
    att = (q @ k.transpose(0, 1, 3, 2)) / math.sqrt(dh)
    causal = jnp.tril(jnp.ones((T, T), jnp.bool_))
    att = jnp.where(causal[None, None], att, -1e9)
    att = jax.nn.softmax(att, axis=-1)
    out = (att @ v).transpose(0, 2, 1, 3).reshape(B, T, D)
    return out @ wo


def forward(params: dict, tokens, cfg: ModelConfig, collect_acts: bool = False):
    """tokens i32[B, T] -> logits f32[B, T, V].

    With collect_acts=True also returns {name: mean-|input activation| per
    in-channel} for every quantizable weight — the calibration statistics
    SmoothQuant/AWQ need.
    """
    B, T = tokens.shape
    x = params["embed"][tokens] + params["pos"][None, :T]
    acts = {}

    def record(name, h):
        if collect_acts:
            acts[name] = jnp.mean(jnp.abs(h), axis=(0, 1))

    for l in range(cfg.n_layer):
        h = _layernorm(x, params[f"l{l}.ln1.g"], params[f"l{l}.ln1.b"])
        for w in ("wq", "wk", "wv", "wo"):
            record(f"l{l}.{w}", h)
        x = x + _attention(h, params[f"l{l}.wq"], params[f"l{l}.wk"],
                           params[f"l{l}.wv"], params[f"l{l}.wo"], cfg.n_head)
        h = _layernorm(x, params[f"l{l}.ln2.g"], params[f"l{l}.ln2.b"])
        record(f"l{l}.w1", h)
        m = jax.nn.gelu(h @ params[f"l{l}.w1"])
        record(f"l{l}.w2", m)
        x = x + m @ params[f"l{l}.w2"]

    x = _layernorm(x, params["lnf.g"], params["lnf.b"])
    record("head", x)
    logits = x @ params["head"]
    if collect_acts:
        return logits, acts
    return logits


def loss_fn(params: dict, tokens, cfg: ModelConfig, loss_mask=None):
    """Next-token cross-entropy; PAD positions are never targets."""
    logits = forward(params, tokens, cfg)
    targets = tokens[:, 1:]
    logits = logits[:, :-1]
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    valid = (targets != corpus.PAD).astype(jnp.float32)
    if loss_mask is not None:
        valid = valid * loss_mask[:, 1:]
    return jnp.sum(nll * valid) / jnp.maximum(jnp.sum(valid), 1.0)


def masked_accuracy(params: dict, tokens, mask, cfg: ModelConfig) -> float:
    """Top-1 accuracy of next-token predictions at masked positions.

    mask[i, t] == 1 scores the prediction made at position t for token t+1
    (the convention of corpus.*_eval_set).
    """
    logits = forward(params, tokens, cfg)
    pred = jnp.argmax(logits[:, :-1], axis=-1)
    targets = tokens[:, 1:]
    m = mask[:, :-1].astype(jnp.float32)
    correct = (pred == targets).astype(jnp.float32) * m
    return float(jnp.sum(correct) / jnp.maximum(jnp.sum(m), 1.0))


def rubric_scores(params: dict, evalsets: dict, cfg: ModelConfig) -> dict:
    """Style / General scores on the paper's [0, 2] rubric scale."""
    out = {}
    for name, (tokens, mask) in evalsets.items():
        acc = masked_accuracy(params, jnp.asarray(tokens), jnp.asarray(mask), cfg)
        out[name] = corpus.accuracy_to_rubric(acc)
    return out
