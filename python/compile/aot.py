"""AOT compiler: lowers the L2/L1 graphs to HLO *text* artifacts.

Python runs exactly once (``make artifacts``); afterwards the Rust binary
is self-contained — it loads these artifacts through PJRT and never touches
Python again.

Interchange format is HLO TEXT, not a serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids which xla_extension 0.5.1 (the
version the published `xla` crate links) rejects; the text parser reassigns
ids and round-trips cleanly (see /opt/xla-example/README.md).

Artifacts:
  daq_sweep_{R}x{C}.hlo.txt   fused DAQ sweep (Pallas kernel) per weight shape
  forward_b{B}.hlo.txt        transformer forward for eval / serving batches
  qdq_128x128.hlo.txt         standalone FP8 quantize–dequantize (quickstart)
  matmul_dq_{B}.hlo.txt       dequantize-matmul serving kernel
  fp8_golden.dts              random inputs + JAX E4M3 outputs; the Rust
                              codec test must reproduce them bit-exactly
  manifest.json               machine-readable index of all of the above
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import corpus, dts, model
from .kernels import delta_metrics, fp8, matmul_dq, ref

N_CANDIDATES = 16   # 1 default + 5 coarse, then 10 fine (padded to 16)
EVAL_BATCH = 64
SERVE_BATCH = 8


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def emit(path: str, lowered) -> int:
    text = to_hlo_text(lowered)
    with open(path, "w") as f:
        f.write(text)
    print(f"  wrote {path} ({len(text)} chars)")
    return len(text)


def sweep_shapes(cfg: model.ModelConfig) -> list:
    """Distinct shapes among quantizable weights."""
    shapes = {
        (cfg.d_model, cfg.d_model),
        (cfg.d_model, cfg.d_ff),
        (cfg.d_ff, cfg.d_model),
        (cfg.d_model, cfg.vocab),
    }
    return sorted(shapes)


def lower_sweep(r: int, c: int):
    def fn(wp, wb, s0_full, alphas):
        return (delta_metrics.daq_sweep_pallas(wp, wb, s0_full, alphas),)

    spec = lambda shape: jax.ShapeDtypeStruct(shape, jnp.float32)
    return jax.jit(fn).lower(
        spec((r, c)), spec((r, c)), spec((r, c)), spec((N_CANDIDATES,)))


def lower_forward(cfg: model.ModelConfig, batch: int, param_names: list):
    def fn(tokens, *flat_params):
        params = dict(zip(param_names, flat_params))
        return (model.forward(params, tokens, cfg),)

    p0 = model.init_params(cfg, jax.random.PRNGKey(0))
    specs = [jax.ShapeDtypeStruct(p0[n].shape, jnp.float32) for n in param_names]
    tok = jax.ShapeDtypeStruct((batch, cfg.seq_len), jnp.int32)
    return jax.jit(fn).lower(tok, *specs)


def lower_qdq(r: int, c: int):
    def fn(w, s_full):
        return (fp8.qdq_scaled_pallas(w, s_full),)

    spec = jax.ShapeDtypeStruct((r, c), jnp.float32)
    return jax.jit(fn).lower(spec, spec)


def lower_matmul_dq(b: int, k: int, n: int):
    def fn(x, codes, s_full):
        return (matmul_dq.matmul_dq_pallas(x, codes, s_full),)

    return jax.jit(fn).lower(
        jax.ShapeDtypeStruct((b, k), jnp.float32),
        jax.ShapeDtypeStruct((k, n), jnp.uint8),
        jax.ShapeDtypeStruct((k, n), jnp.float32))


def write_golden(out: str) -> None:
    """Golden vectors for the Rust FP8 codec: all 256 codes + random f32s."""
    rng = np.random.default_rng(42)
    xs = np.concatenate([
        rng.normal(0, 1, 4096), rng.normal(0, 64, 4096),
        rng.uniform(-480, 480, 4096), rng.normal(0, 1e-3, 4096),
        np.array([0.0, 448.0, -448.0, 2.0 ** -9, 2.0 ** -10, 2.0 ** -6,
                  1e-8, 449.0, -1000.0, 0.4375], np.float32),
    ]).astype(np.float32)
    qdq = np.asarray(ref.qdq_e4m3(xs), np.float32)
    codes = np.asarray(ref.encode_e4m3(xs), np.uint8)
    all_codes = np.arange(256, dtype=np.uint8)
    decoded = np.asarray(ref.decode_e4m3(all_codes), np.float32)
    # the two NaN codes decode to NaN; store a finite sentinel + flag
    nan_mask = np.isnan(decoded).astype(np.uint8)
    decoded = np.nan_to_num(decoded, nan=0.0)
    dts.write_dts(f"{out}/fp8_golden.dts", {
        "inputs": xs, "qdq": qdq, "codes": codes,
        "all_codes_decoded": decoded, "all_codes_nan": nan_mask,
    }, {"kind": "fp8_golden"})
    print(f"  wrote {out}/fp8_golden.dts ({xs.size} vectors)")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)
    cfg = model.ModelConfig()
    p0 = model.init_params(cfg, jax.random.PRNGKey(0))
    param_names = sorted(p0.keys())

    manifest = {
        "n_candidates": N_CANDIDATES,
        "eval_batch": EVAL_BATCH,
        "serve_batch": SERVE_BATCH,
        "seq_len": cfg.seq_len,
        "vocab": cfg.vocab,
        "d_model": cfg.d_model,
        "n_layer": cfg.n_layer,
        "n_head": cfg.n_head,
        "d_ff": cfg.d_ff,
        "param_order": param_names,
        "param_shapes": {n: list(p0[n].shape) for n in param_names},
        "quantizable": model.quantizable_names(cfg),
        "sweeps": [],
        "forwards": [],
    }

    print("lowering DAQ sweep kernels (Pallas):")
    for r, c in sweep_shapes(cfg):
        name = f"daq_sweep_{r}x{c}.hlo.txt"
        emit(f"{args.out}/{name}", lower_sweep(r, c))
        manifest["sweeps"].append({"shape": [r, c], "file": name})

    print("lowering forward graphs:")
    for b in (EVAL_BATCH, SERVE_BATCH):
        name = f"forward_b{b}.hlo.txt"
        emit(f"{args.out}/{name}", lower_forward(cfg, b, param_names))
        manifest["forwards"].append({"batch": b, "file": name})

    print("lowering auxiliary kernels:")
    emit(f"{args.out}/qdq_128x128.hlo.txt", lower_qdq(128, 128))
    manifest["qdq"] = {"shape": [128, 128], "file": "qdq_128x128.hlo.txt"}
    emit(f"{args.out}/matmul_dq_b{SERVE_BATCH}.hlo.txt",
         lower_matmul_dq(SERVE_BATCH, cfg.d_model, cfg.d_ff))
    manifest["matmul_dq"] = {
        "shape": [SERVE_BATCH, cfg.d_model, cfg.d_ff],
        "file": f"matmul_dq_b{SERVE_BATCH}.hlo.txt"}

    write_golden(args.out)

    with open(f"{args.out}/manifest.json", "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"  wrote {args.out}/manifest.json")


if __name__ == "__main__":
    main()
