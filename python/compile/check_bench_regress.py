#!/usr/bin/env python3
"""Bench-regression gate for the CI `bench` job.

Compares the bench-smoke `BENCH_sweep.json` artifact against the
committed `rust/BENCH_baseline.json` and fails (exit 1) when any
`pipeline-*` or `serve-*` row regresses by more than the threshold in
its throughput metric (Melem/s for the pipeline rows, tokens/s for the
serving rows).

Rows are keyed by (variant, shape, granularity) — `workers` is excluded
on purpose: the bench sizes its worker pool from the runner's core
count, and a hosted-runner fleet change must not masquerade as a code
regression. Only rows present in BOTH files are compared; if the files
share no gated rows at all the gate fails loudly (a silently vacuous
gate is worse than none), telling the operator to re-baseline.

Usage:
    check_bench_regress.py --current rust/BENCH_sweep.json \
                           --baseline rust/BENCH_baseline.json \
                           [--threshold 0.15] [--checksum-overhead 0.05] \
                           [--write-baseline]

`--write-baseline` regenerates the baseline file from the current
run's pipeline rows (used to commit a fresh baseline from a CI
artifact) instead of gating.

`--checksum-overhead X` adds an *intra-run* gate: for every
(shape, granularity) that has both a `pipeline-streaming` row (CRC off)
and a `pipeline-streaming-checksum` row (CRC on), the checksummed
throughput must be within X of the plain one. Comparing two rows of the
same run makes the integrity-layer price machine-independent — runner
noise cancels out — so it can be gated far tighter than the
cross-run threshold.

`--telemetry-overhead X` is the same intra-run pattern for the
telemetry layer: `pipeline-streaming-telemetry` vs `pipeline-streaming`
(Melem/s) and `serve-quantized-telemetry` vs `serve-quantized`
(tokens/s) must each stay within X of the uninstrumented row.

`--mt-scaling X` is the intra-run gate for slot-parallel decode:
`serve-quantized-mt` tokens/s must be >= X * `serve-quantized` for
every (shape, granularity) that has both rows. Like the other
intra-run gates it compares two rows of the same run, so runner noise
cancels and the scaling floor is machine-independent (given the
runner's advertised core count).

`--simd-speedup X` is the intra-run gate for the SIMD kernel layer:
`pipeline-inmemory` Melem/s must be >= X * `pipeline-scalar` (the same
workload re-run with dispatch forced to the scalar reference), and
`serve-quantized` tokens/s must be >= SERVE_SIMD_SCALING *
`serve-quantized-scalar`. Rows carry the dispatched ISA in a `simd`
field ("avx2"/"sse4.1"/"neon"/"scalar"); when the SIMD row itself
dispatched "scalar" — the runner has no vector ISA — the pair is
skipped with a warning rather than failed, so the gate is meaningful
on AVX2/NEON runners and harmless elsewhere. Baselines written before
the field existed are still accepted: `simd` is carried through
--write-baseline when present but never required.

Exit code 0 = no regression beyond the threshold.
"""

from __future__ import annotations

import argparse
import json
import sys

GATED_PREFIXES = ("pipeline-", "serve-")


def key(row: dict) -> tuple:
    return (row["variant"], row["shape"], row["granularity"])


def metric(row: dict) -> tuple:
    """(name, value) of a row's throughput metric: Melem/s for the
    pipeline rows, tokens/s for the serving rows."""
    if "melem_per_s" in row:
        return ("melem_per_s", row["melem_per_s"])
    return ("tokens_per_s", row["tokens_per_s"])


def pipeline_rows(doc: dict) -> dict:
    out = {}
    for row in doc.get("rows", []):
        if row.get("variant", "").startswith(GATED_PREFIXES):
            out[key(row)] = row
    return out


def load(path: str) -> dict:
    try:
        with open(path) as f:
            return json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        sys.exit(f"error: cannot read {path}: {e}")


def write_baseline(path: str, current: dict, threshold: float) -> None:
    rows = sorted(pipeline_rows(current).values(), key=key)
    if not rows:
        sys.exit("error: current run has no pipeline-* rows to baseline")
    doc = {
        "bench": "sweep",
        "gate": "check_bench_regress.py",
        "threshold": threshold,
        "rows": [
            {
                "variant": r["variant"],
                "shape": r["shape"],
                "granularity": r["granularity"],
                "workers": r.get("workers"),
                "simd": r.get("simd"),
                "mean_ms": r.get("mean_ms"),
                metric(r)[0]: metric(r)[1],
            }
            for r in rows
        ],
    }
    with open(path, "w") as f:
        json.dump(doc, f, indent=2)
        f.write("\n")
    print(f"wrote {path} ({len(rows)} pipeline rows)")


def check_checksum_overhead(cur_rows: dict, overhead: float) -> None:
    """Intra-run gate: checksummed streaming throughput within
    `overhead` of the checksum-free row for every (shape, granularity)
    pair present. Exits non-zero on breach or if no pair exists."""
    pairs = 0
    breaches = []
    for (variant, shape, gran), plain in sorted(cur_rows.items()):
        if variant != "pipeline-streaming":
            continue
        crc = cur_rows.get(("pipeline-streaming-checksum", shape, gran))
        if crc is None:
            continue
        pairs += 1
        mname, mplain = metric(plain)
        mcrc = crc.get(mname, 0.0)
        floor = mplain * (1.0 - overhead)
        ratio = mcrc / mplain if mplain else 0.0
        status = "ok" if mcrc >= floor else "CHECKSUM OVERHEAD"
        print(
            f"{status:>10}: {shape}/{gran}  checksummed {mcrc:.2f} vs "
            f"plain {mplain:.2f} Melem/s ({ratio:.3f}x, floor {floor:.2f})"
        )
        if mcrc < floor:
            breaches.append((shape, gran))
    if pairs == 0:
        sys.exit(
            "error: --checksum-overhead was requested but no "
            "(pipeline-streaming, pipeline-streaming-checksum) row pair "
            "exists in the current run"
        )
    if breaches:
        names = ", ".join("/".join(b) for b in breaches)
        sys.exit(
            f"error: checksum overhead exceeds {overhead:.0%} of the "
            f"checksum-free streaming throughput on: {names}"
        )
    print(f"ok: checksum overhead within {overhead:.0%} on {pairs} pair(s)")


# (uninstrumented variant, instrumented variant) pairs priced by the
# --telemetry-overhead intra-run gate
TELEMETRY_PAIRS = (
    ("pipeline-streaming", "pipeline-streaming-telemetry"),
    ("serve-quantized", "serve-quantized-telemetry"),
)


def check_telemetry_overhead(cur_rows: dict, overhead: float) -> None:
    """Intra-run gate: instrumented throughput within `overhead` of the
    matching uninstrumented row for every TELEMETRY_PAIRS pair present.
    Exits non-zero on breach or if no pair exists at all."""
    pairs = 0
    breaches = []
    for plain_variant, tel_variant in TELEMETRY_PAIRS:
        for (variant, shape, gran), plain in sorted(cur_rows.items()):
            if variant != plain_variant:
                continue
            tel = cur_rows.get((tel_variant, shape, gran))
            if tel is None:
                continue
            pairs += 1
            mname, mplain = metric(plain)
            mtel = tel.get(mname, 0.0)
            floor = mplain * (1.0 - overhead)
            ratio = mtel / mplain if mplain else 0.0
            unit = "Melem/s" if mname == "melem_per_s" else "tok/s"
            status = "ok" if mtel >= floor else "TELEMETRY OVERHEAD"
            print(
                f"{status:>10}: {tel_variant} {shape}/{gran}  "
                f"instrumented {mtel:.2f} vs plain {mplain:.2f} {unit} "
                f"({ratio:.3f}x, floor {floor:.2f})"
            )
            if mtel < floor:
                breaches.append((tel_variant, shape, gran))
    if pairs == 0:
        sys.exit(
            "error: --telemetry-overhead was requested but no "
            "(uninstrumented, -telemetry) row pair exists in the current run"
        )
    if breaches:
        names = ", ".join("/".join(b) for b in breaches)
        sys.exit(
            f"error: telemetry overhead exceeds {overhead:.0%} of the "
            f"uninstrumented throughput on: {names}"
        )
    print(f"ok: telemetry overhead within {overhead:.0%} on {pairs} pair(s)")


def check_mt_scaling(cur_rows: dict, scaling: float) -> None:
    """Intra-run gate: multi-threaded serve throughput at least
    `scaling`x the single-threaded quantized row for every
    (shape, granularity) pair present. Exits non-zero on breach or if
    no pair exists at all."""
    pairs = 0
    breaches = []
    for (variant, shape, gran), serial in sorted(cur_rows.items()):
        if variant != "serve-quantized":
            continue
        mt = cur_rows.get(("serve-quantized-mt", shape, gran))
        if mt is None:
            continue
        pairs += 1
        mname, mserial = metric(serial)
        mmt = mt.get(mname, 0.0)
        floor = mserial * scaling
        ratio = mmt / mserial if mserial else 0.0
        status = "ok" if mmt >= floor else "MT SCALING"
        print(
            f"{status:>10}: {shape}/{gran}  mt {mmt:.2f} vs "
            f"serial {mserial:.2f} tok/s ({ratio:.3f}x, floor {floor:.2f})"
        )
        if mmt < floor:
            breaches.append((shape, gran))
    if pairs == 0:
        sys.exit(
            "error: --mt-scaling was requested but no "
            "(serve-quantized, serve-quantized-mt) row pair exists in "
            "the current run"
        )
    if breaches:
        names = ", ".join("/".join(b) for b in breaches)
        sys.exit(
            f"error: serve-quantized-mt scales below {scaling:.2f}x of "
            f"the single-threaded quantized throughput on: {names}"
        )
    print(f"ok: mt scaling >= {scaling:.2f}x on {pairs} pair(s)")


# Serve pair floor for --simd-speedup: the decode path spends a smaller
# share of its time in the vectorized kernels than the quantize pipeline
# (attention, KV bookkeeping and sampling are untouched scalar code), so
# its intra-run floor is fixed lower than the pipeline one.
SERVE_SIMD_SCALING = 1.5

# (SIMD-dispatched variant, forced-scalar companion) pairs priced by the
# --simd-speedup intra-run gate; the pipeline pair uses the flag value as
# its floor, the serve pair uses SERVE_SIMD_SCALING.
SIMD_PAIRS = (
    ("pipeline-inmemory", "pipeline-scalar"),
    ("serve-quantized", "serve-quantized-scalar"),
)


def check_simd_speedup(cur_rows: dict, speedup: float) -> None:
    """Intra-run gate: SIMD-dispatched throughput at least `speedup`x
    the forced-scalar companion for the pipeline pair (Melem/s) and at
    least SERVE_SIMD_SCALING x for the serve pair (tokens/s). Pairs
    whose SIMD row reports `simd: "scalar"` (the runner has no vector
    ISA, so both rows ran the same code) are skipped with a warning.
    Exits non-zero on breach or if no pair exists at all."""
    pairs = 0
    skipped = 0
    breaches = []
    for (simd_variant, scalar_variant), floor_ratio in zip(
        SIMD_PAIRS, (speedup, SERVE_SIMD_SCALING)
    ):
        for (variant, shape, gran), fast in sorted(cur_rows.items()):
            if variant != simd_variant:
                continue
            scalar = cur_rows.get((scalar_variant, shape, gran))
            if scalar is None:
                continue
            isa = fast.get("simd") or "scalar"
            if isa == "scalar":
                skipped += 1
                print(
                    f"      skip: {simd_variant} {shape}/{gran} dispatched "
                    f"scalar (no vector ISA on this runner)"
                )
                continue
            pairs += 1
            mname, mfast = metric(fast)
            mscalar = scalar.get(mname, 0.0)
            floor = mscalar * floor_ratio
            ratio = mfast / mscalar if mscalar else 0.0
            unit = "Melem/s" if mname == "melem_per_s" else "tok/s"
            status = "ok" if mfast >= floor else "SIMD SPEEDUP"
            print(
                f"{status:>10}: {simd_variant} [{isa}] {shape}/{gran}  "
                f"{mfast:.2f} vs scalar {mscalar:.2f} {unit} "
                f"({ratio:.3f}x, floor {floor_ratio:.2f}x)"
            )
            if mfast < floor:
                breaches.append((simd_variant, shape, gran))
    if pairs == 0 and skipped == 0:
        sys.exit(
            "error: --simd-speedup was requested but no "
            "(pipeline-inmemory, pipeline-scalar) or "
            "(serve-quantized, serve-quantized-scalar) row pair exists "
            "in the current run"
        )
    if pairs == 0:
        print(
            "warning: --simd-speedup skipped entirely — every pair "
            "dispatched scalar on this runner"
        )
        return
    if breaches:
        names = ", ".join("/".join(b) for b in breaches)
        sys.exit(
            "error: SIMD dispatch speeds up less than the required "
            f"intra-run floor over the forced-scalar companion on: {names}"
        )
    print(f"ok: simd speedup floors met on {pairs} pair(s)")


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--current", required=True, help="BENCH_sweep.json from this run")
    ap.add_argument("--baseline", required=True, help="committed BENCH_baseline.json")
    ap.add_argument(
        "--threshold",
        type=float,
        default=0.15,
        help="max allowed fractional Melem/s regression (default 0.15)",
    )
    ap.add_argument(
        "--checksum-overhead",
        type=float,
        default=None,
        help="max allowed intra-run throughput cost of per-payload "
        "checksums: pipeline-streaming-checksum vs pipeline-streaming "
        "(disabled unless given)",
    )
    ap.add_argument(
        "--telemetry-overhead",
        type=float,
        default=None,
        help="max allowed intra-run throughput cost of live telemetry: "
        "each *-telemetry row vs its uninstrumented pair "
        "(disabled unless given)",
    )
    ap.add_argument(
        "--mt-scaling",
        type=float,
        default=None,
        help="min required intra-run throughput ratio of "
        "serve-quantized-mt vs serve-quantized "
        "(disabled unless given)",
    )
    ap.add_argument(
        "--simd-speedup",
        type=float,
        default=None,
        help="min required intra-run throughput ratio of the "
        "SIMD-dispatched pipeline row vs its forced-scalar companion "
        "(serve pair uses a fixed 1.5x floor; disabled unless given)",
    )
    ap.add_argument(
        "--write-baseline",
        action="store_true",
        help="regenerate the baseline from the current run instead of gating",
    )
    args = ap.parse_args()

    current = load(args.current)
    if args.write_baseline:
        write_baseline(args.baseline, current, args.threshold)
        return 0

    baseline = load(args.baseline)
    base_rows = pipeline_rows(baseline)
    cur_rows = pipeline_rows(current)
    if not base_rows:
        sys.exit(f"error: {args.baseline} has no pipeline-*/serve-* rows")
    if not cur_rows:
        sys.exit(f"error: {args.current} has no pipeline-*/serve-* rows")
    if args.checksum_overhead is not None:
        check_checksum_overhead(cur_rows, args.checksum_overhead)
    if args.telemetry_overhead is not None:
        check_telemetry_overhead(cur_rows, args.telemetry_overhead)
    if args.mt_scaling is not None:
        check_mt_scaling(cur_rows, args.mt_scaling)
    if args.simd_speedup is not None:
        check_simd_speedup(cur_rows, args.simd_speedup)

    compared = 0
    regressions = []
    for k, base in sorted(base_rows.items()):
        cur = cur_rows.get(k)
        if cur is None:
            # shape sets differ between DAQ_BENCH_FAST and full runs;
            # a missing counterpart is reported but only the total
            # overlap is enforced
            print(f"skip: {k} not in current run")
            continue
        compared += 1
        mname, mbase = metric(base)
        if mname not in cur:
            print(f"skip: {k} metric {mname} missing from current run")
            compared -= 1
            continue
        mcur = cur[mname]
        floor = mbase * (1.0 - args.threshold)
        ratio = mcur / mbase if mbase else 0.0
        status = "REGRESSION" if mcur < floor else "ok"
        unit = "Melem/s" if mname == "melem_per_s" else "tok/s"
        print(
            f"{status:>10}: {'/'.join(k)}  "
            f"{mcur:.2f} vs baseline {mbase:.2f} "
            f"{unit} ({ratio:.2f}x, floor {floor:.2f})"
        )
        if status == "REGRESSION":
            regressions.append(k)

    if compared == 0:
        sys.exit(
            "error: no pipeline-*/serve-* rows are shared between the baseline "
            "and this run — the baseline is stale; regenerate it with "
            "--write-baseline from a fresh CI artifact"
        )
    if regressions:
        names = ", ".join("/".join(k) for k in regressions)
        sys.exit(
            f"error: {len(regressions)}/{compared} gated rows regressed "
            f">{args.threshold:.0%} vs baseline: {names}"
        )
    print(f"ok: {compared} gated rows within {args.threshold:.0%} of baseline")
    return 0


if __name__ == "__main__":
    sys.exit(main())
