"""L1 Pallas kernel: dequantize-matmul for the FP8 serving path.

y[B,N] = x[B,K] @ (decode_e4m3(codes[K,N]) * scale[K,N])

The weight stays in its 1-byte storage format in HBM; each VMEM tile is
decoded in-register and immediately consumed by the matmul, so the f32
weight never materializes in HBM — the memory-traffic win FP8 serving is
about. Accumulation over the K grid axis happens in the f32 output tile.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _decode_e4m3_inreg(code):
    code = code.astype(jnp.int32)
    sign = (code >> 7) & 1
    exp = (code >> 3) & 0xF
    mant = code & 0x7
    sub_val = mant.astype(jnp.float32) * 2.0 ** -9
    norm_val = jnp.ldexp((8 + mant).astype(jnp.float32), exp - 10)
    val = jnp.where(exp == 0, sub_val, norm_val)
    return jnp.where(sign == 1, -val, val)


def _matmul_dq_kernel(x_ref, codes_ref, s_ref, o_ref):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    w = _decode_e4m3_inreg(codes_ref[...]) * s_ref[...]
    o_ref[...] += x_ref[...] @ w


@functools.partial(jax.jit, static_argnames=("block_b", "block_k", "block_n"))
def matmul_dq_pallas(x, codes, scale_full, block_b=32, block_k=128, block_n=128):
    """x f32[B,K] @ dequant(codes u8[K,N] · scale[K,N]) -> f32[B,N]."""
    b, kdim = x.shape
    k2, n = codes.shape
    assert kdim == k2, (x.shape, codes.shape)
    bb, bk, bn = min(block_b, b), min(block_k, kdim), min(block_n, n)
    assert b % bb == 0 and kdim % bk == 0 and n % bn == 0
    grid = (b // bb, n // bn, kdim // bk)
    return pl.pallas_call(
        _matmul_dq_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bb, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bb, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((b, n), jnp.float32),
        interpret=True,  # CPU PJRT cannot execute Mosaic custom-calls
    )(
        x.astype(jnp.float32),
        codes.astype(jnp.uint8),
        jnp.broadcast_to(scale_full, (kdim, n)).astype(jnp.float32),
    )
