"""L1 Pallas kernel: the fused DAQ sweep — the paper's compute hot-spot.

For every candidate scale multiplier alpha (Algorithm 1 lines 7–24), the
search needs the three metrics of §2.3 evaluated on the full weight tensor.
Done naively that is NC full quantize + 3 reduction passes. This kernel
fuses everything into a single pass per tile: for one (128×128) VMEM tile
of (W_post, W_base, s0) it quantizes under every candidate and accumulates
the *sufficient statistics* of all three metrics simultaneously:

    [ sign_agree_count, Δq·Δp, ‖Δq‖², ‖Δp‖², ‖Wq−Wp‖², N ]

from which SignRate, CosSim, MSE and ΔW-L2 are all closed-form
(ref.stats_to_metrics). The candidate axis is the innermost grid dimension,
so each weight tile is fetched from HBM once and reused for all NC
candidates — the TPU analogue of the shared-memory reuse a GPU
implementation would get from a threadblock loop (DESIGN.md
§Hardware-Adaptation).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .fp8 import _qdq_e4m3_inreg

N_STATS = 6


def _sweep_kernel(wp_ref, wb_ref, s0_ref, alpha_ref, out_ref):
    r = pl.program_id(0)
    c = pl.program_id(1)

    @pl.when((r == 0) & (c == 0))
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    alpha = alpha_ref[0]
    wp = wp_ref[...]
    wb = wb_ref[...]
    s = s0_ref[...] * alpha

    # reciprocal-multiply qdq with saturating reciprocal: same canonical
    # form as ref.qdq_scaled and the Rust sweep engines (bit-exact
    # cross-engine sign counts)
    s_inv = jnp.minimum(1.0 / s, jnp.float32(jnp.finfo(jnp.float32).max))
    wq = _qdq_e4m3_inreg(wp * s_inv) * s
    dp = wp - wb
    dq = wq - wb
    err = wq - wp

    agree = jnp.sum((jnp.sign(dp) == jnp.sign(dq)).astype(jnp.float32))
    dot = jnp.sum(dq * dp)
    nq = jnp.sum(dq * dq)
    npost = jnp.sum(dp * dp)
    sq = jnp.sum(err * err)
    n = jnp.float32(wp.size)

    out_ref[...] += jnp.stack([agree, dot, nq, npost, sq, n]).reshape(1, N_STATS)


@functools.partial(jax.jit, static_argnames=("block_r", "block_c"))
def daq_sweep_pallas(w_post, w_base, s0_full, alphas, block_r=128, block_c=128):
    """Fused sweep: returns stats f32[NC, 6] for NC candidate multipliers.

    `s0_full` is the default scale broadcast to w.shape (granularity-
    agnostic, see fp8.qdq_scaled_pallas). Requires tensor dims divisible by
    the tile dims (model dims are multiples of 64; tiles clamp to the dim).
    """
    r, c = w_post.shape
    (nc,) = alphas.shape
    br, bc = min(block_r, r), min(block_c, c)
    assert r % br == 0 and c % bc == 0, (r, c, br, bc)
    grid = (r // br, c // bc, nc)

    tile = pl.BlockSpec((br, bc), lambda i, j, k: (i, j))
    return pl.pallas_call(
        _sweep_kernel,
        grid=grid,
        in_specs=[
            tile,  # w_post
            tile,  # w_base
            tile,  # s0 (expanded)
            pl.BlockSpec((1,), lambda i, j, k: (k,)),  # this candidate's alpha
        ],
        out_specs=pl.BlockSpec((1, N_STATS), lambda i, j, k: (k, 0)),
        out_shape=jax.ShapeDtypeStruct((nc, N_STATS), jnp.float32),
        interpret=True,  # CPU PJRT cannot execute Mosaic custom-calls
    )(
        w_post.astype(jnp.float32),
        w_base.astype(jnp.float32),
        jnp.broadcast_to(s0_full, (r, c)).astype(jnp.float32),
        alphas.astype(jnp.float32),
    )
