"""L1 Pallas kernel: FP8 (E4M3) quantize–dequantize.

This is the numeric core of the paper's Q_s(W) operator (Eq. 4). The kernel
tiles the weight into VMEM blocks and applies the saturating RNE
quantize–dequantize in-register.

TPU adaptation note (DESIGN.md §Hardware-Adaptation): on a real TPU the
dequantized bf16 tile would stay VMEM-resident and feed the MXU; on the CPU
PJRT plugin we must run interpret=True, which lowers to plain HLO — the
BlockSpec structure (one HBM read per tile) is what carries over.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import E4M3_MANT_BITS, E4M3_MAX, E4M3_MIN_NORMAL_EXP


def _qdq_e4m3_inreg(x):
    """In-register E4M3 quantize–dequantize (same math as ref.qdq_e4m3)."""
    a = jnp.clip(x, -E4M3_MAX, E4M3_MAX)
    mag = jnp.abs(a)
    _, e = jnp.frexp(mag)
    exp = jnp.clip(e - 1, E4M3_MIN_NORMAL_EXP, None)
    # ldexp not exp2: exact, fusion-context-independent (see ref.qdq_e4m3)
    step = jnp.ldexp(jnp.float32(1.0), exp - E4M3_MANT_BITS)
    q = jnp.round(a / step) * step
    return jnp.where(mag == 0.0, jnp.zeros_like(q), q)


def _qdq_kernel(w_ref, s_ref, o_ref):
    """One tile: o = qdq(w · s⁻¹) * s with s broadcast over the tile.

    Reciprocal-multiply, matching ref.qdq_scaled and the Rust
    `fp8::qdq_e4m3_scaled` bit-for-bit (the cross-layer golden contract).
    """
    s = s_ref[...]
    w = w_ref[...]
    # saturating reciprocal (see ref.qdq_scaled / Rust fp8::recip_scale)
    s_inv = jnp.minimum(1.0 / s, jnp.float32(jnp.finfo(jnp.float32).max))
    o_ref[...] = _qdq_e4m3_inreg(w * s_inv) * s


@functools.partial(jax.jit, static_argnames=("block_r", "block_c"))
def qdq_scaled_pallas(w, scale_full, block_r=128, block_c=128):
    """Pallas quantize–dequantize of a 2-D weight with an elementwise scale.

    `scale_full` must already be broadcast to w.shape (use
    ref.expand_block_scale / jnp.broadcast_to); this keeps the kernel
    granularity-agnostic — block-wise, per-channel and per-tensor all reduce
    to an elementwise scale field.
    """
    r, c = w.shape
    br, bc = min(block_r, r), min(block_c, c)
    assert r % br == 0 and c % bc == 0, (r, c, br, bc)
    grid = (r // br, c // bc)
    return pl.pallas_call(
        _qdq_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((br, bc), lambda i, j: (i, j)),
            pl.BlockSpec((br, bc), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((br, bc), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((r, c), jnp.float32),
        interpret=True,  # CPU PJRT cannot execute Mosaic custom-calls
    )(w.astype(jnp.float32), jnp.broadcast_to(scale_full, (r, c)).astype(jnp.float32))
