"""Pure-jnp oracles for every Pallas kernel — the CORE correctness signal.

These implementations favour obviousness over speed; pytest asserts the
Pallas kernels (and, via golden files, the Rust implementations) match them
exactly (FP8 codec) or to f32 tolerance (reductions).

FP8 E4M3 follows the OCP "E4M3FN" convention used by the paper's FP8
pipeline: 1 sign / 4 exponent (bias 7) / 3 mantissa bits, NO infinities,
max finite ±448, subnormal step 2^-9, saturating round-to-nearest-even.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

E4M3_MAX = 448.0
E4M3_MIN_NORMAL_EXP = -6   # smallest normal exponent
E4M3_MANT_BITS = 3


def qdq_e4m3(x):
    """Quantize-dequantize x onto the E4M3 value grid.

    Saturating round-to-nearest-even. Exact: within a binade the grid is
    uniform with step 2^(e-3), and round-half-even in units of the step is
    identical to RNE on the mantissa; exponent extraction uses frexp so no
    log2 rounding hazards exist at binade boundaries.
    """
    x = jnp.asarray(x, jnp.float32)
    a = jnp.clip(x, -E4M3_MAX, E4M3_MAX)
    mag = jnp.abs(a)
    _, e = jnp.frexp(mag)              # mag = m * 2^e with m in [0.5, 1)
    exp = jnp.clip(e - 1, E4M3_MIN_NORMAL_EXP, None)   # floor(log2 mag), subnormal floor
    # ldexp (exact exponent manipulation) rather than exp2: XLA's vectorized
    # exp2 is a polynomial approximation whose 1-ulp wobble can differ
    # between fusion contexts, breaking bit-identity between the Pallas
    # kernel and this oracle.
    step = jnp.ldexp(jnp.float32(1.0), exp - E4M3_MANT_BITS)
    q = jnp.round(a / step) * step
    return jnp.where(mag == 0.0, jnp.zeros_like(q), q).astype(jnp.float32)


def encode_e4m3(x) -> jnp.ndarray:
    """f32 -> E4M3 byte codes (sign<<7 | biased_exp<<3 | mantissa)."""
    q = qdq_e4m3(x)
    sign = (q < 0).astype(jnp.uint32)
    mag = jnp.abs(q)
    _, e = jnp.frexp(mag)
    exp = jnp.clip(e - 1, E4M3_MIN_NORMAL_EXP, 8)
    sub = mag < 2.0 ** E4M3_MIN_NORMAL_EXP
    mant = jnp.where(
        sub,
        mag * 512.0,                                  # subnormal: mag / 2^-9
        jnp.ldexp(mag, -exp) * 8.0 - 8.0,
    )
    expf = jnp.where(sub, 0, exp + 7).astype(jnp.uint32)
    code = (sign << 7) | (expf << 3) | jnp.round(mant).astype(jnp.uint32)
    return code.astype(jnp.uint8)


def decode_e4m3(code) -> jnp.ndarray:
    """E4M3 byte codes -> f32. The NaN code (exp=15, mant=7) decodes to NaN."""
    code = jnp.asarray(code, jnp.uint8).astype(jnp.int32)
    sign = (code >> 7) & 1
    exp = (code >> 3) & 0xF
    mant = code & 0x7
    sub_val = mant.astype(jnp.float32) * 2.0 ** -9
    norm_val = jnp.ldexp((8 + mant).astype(jnp.float32), exp - 7 - E4M3_MANT_BITS)
    val = jnp.where(exp == 0, sub_val, norm_val)
    val = jnp.where((exp == 15) & (mant == 7), jnp.nan, val)
    return jnp.where(sign == 1, -val, val).astype(jnp.float32)


def qdq_scaled(w, scale):
    """The paper's Q_s(W) = DeQuant(Quant(W, s), s) with broadcastable scale.

    Reciprocal-multiply form (w · s⁻¹, not w / s): the canonical scaled
    projection shared bit-for-bit with the Rust engines
    (`fp8::qdq_e4m3_scaled`), whose sweep hot loop hoists the reciprocal
    out of the inner loop. The reciprocal saturates at f32 max (Rust
    `fp8::recip_scale`) so a subnormal s·α cannot turn zero weights into
    0·∞ = NaN."""
    scale_inv = jnp.minimum(1.0 / scale, jnp.float32(jnp.finfo(jnp.float32).max))
    return qdq_e4m3(w * scale_inv) * scale


# ---------------------------------------------------------------------------
# Scale initialization (Algorithm 1 line 3: s0 = absmax / Qmax)
# ---------------------------------------------------------------------------

def absmax_scale_block(w, block=128):
    """Block-wise s0 over `block`×`block` tiles; shape (ceil(R/b), ceil(C/b)).

    Tiles at the edge cover the remainder. Scale of an all-zero block is 1
    (any positive value works; 1 avoids div-by-zero)."""
    r, c = w.shape
    nr, nc = -(-r // block), -(-c // block)
    pr, pc = nr * block - r, nc * block - c
    wp = jnp.pad(jnp.abs(w), ((0, pr), (0, pc)))
    tiles = wp.reshape(nr, block, nc, block)
    amax = jnp.max(tiles, axis=(1, 3))
    return jnp.where(amax > 0, amax / E4M3_MAX, 1.0).astype(jnp.float32)


def absmax_scale_channel(w):
    """Per-output-channel (column) s0; shape (1, C)."""
    amax = jnp.max(jnp.abs(w), axis=0, keepdims=True)
    return jnp.where(amax > 0, amax / E4M3_MAX, 1.0).astype(jnp.float32)


def expand_block_scale(s0, shape, block=128):
    """Broadcast a block-scale grid back to the full weight shape."""
    r, c = shape
    s = jnp.repeat(jnp.repeat(s0, block, axis=0), block, axis=1)
    return s[:r, :c]


# ---------------------------------------------------------------------------
# Delta metrics (paper §2.3)
# ---------------------------------------------------------------------------

def delta_stats(w_post, w_base, w_quant):
    """Sufficient statistics for all three metrics, as a length-6 vector:
    [sign_agree_count, dot(dq,dp), ||dq||^2, ||dp||^2, sq_err, n]."""
    dp = (w_post - w_base).ravel()
    dq = (w_quant - w_base).ravel()
    agree = jnp.sum(jnp.sign(dp) == jnp.sign(dq)).astype(jnp.float32)
    dot = jnp.dot(dq, dp)
    nq = jnp.dot(dq, dq)
    npost = jnp.dot(dp, dp)
    err = w_quant.ravel() - w_post.ravel()
    sq = jnp.dot(err, err)
    n = jnp.float32(dp.size)
    return jnp.stack([agree, dot, nq, npost, sq, n])


def stats_to_metrics(stats):
    """stats (…,6) -> dict of SignRate / CosSim / MSE / delta L2."""
    agree, dot, nq, npost, sq, n = [stats[..., i] for i in range(6)]
    eps = 1e-30
    return {
        "sign_rate": agree / n,
        "cos_sim": dot / jnp.sqrt(jnp.maximum(nq * npost, eps)),
        "mse": sq / n,
        "delta_l2": jnp.sqrt(nq),
    }


def sweep_ref(w_post, w_base, s0_full, alphas):
    """Reference DAQ sweep: for each candidate alpha, quantize with
    s = alpha * s0 and emit the 6 sufficient statistics. Returns (NC, 6)."""
    outs = []
    for a in np.asarray(alphas):
        wq = qdq_scaled(w_post, s0_full * jnp.float32(a))
        outs.append(delta_stats(w_post, w_base, wq))
    return jnp.stack(outs)


# ---------------------------------------------------------------------------
# Dequantize-matmul (serving path)
# ---------------------------------------------------------------------------

def matmul_dq_ref(x, codes, scale_full):
    """x f32[B,K] @ dequant(codes u8[K,N], scale) -> f32[B,N]."""
    w = decode_e4m3(codes) * scale_full
    return x @ w
