"""Fast SFT/quantization tuning loop: pretrain once (cached), then sweep
SFT hyperparameters and measure the DAQ effect sizes.

We are looking for the paper's operating regime:
  - post-trained Style high (>= 1.6/2)
  - AbsMax FP8 quantization degrades Style substantially
  - DAQ sign/cos scale search recovers it; MSE search does not

Usage: cd python && python -m compile.tune
"""

from __future__ import annotations

import os
import sys

import jax
import jax.numpy as jnp
import numpy as np

from . import corpus, dts, model, train
from .kernels import ref
from .pilot import quantize_model

BASE_CACHE = "/tmp/daq_base.dts"


def get_base(cfg, pre_steps=1500):
    if os.path.exists(BASE_CACHE):
        base, meta = dts.read_dts(BASE_CACHE)
        if int(meta.get("n_layer", -1)) == cfg.n_layer and \
           int(meta.get("pre_steps", -1)) == pre_steps:
            print("using cached base")
            return base
    params = model.init_params(cfg, jax.random.PRNGKey(0))
    params, _ = train.train_phase(params, cfg, corpus.pretrain_batch,
                                  pre_steps, 64, 1.5e-3, 100, seed=1,
                                  label="pretrain")
    base = train.params_to_numpy(params)
    dts.write_dts(BASE_CACHE, base, {"n_layer": cfg.n_layer,
                                     "pre_steps": pre_steps})
    return base


def main():
    cfg = model.ModelConfig()
    pre_steps = int(os.environ.get("PRE_STEPS", "1500"))
    base = get_base(cfg, pre_steps)

    erng = np.random.default_rng(1000)
    st_tok, st_mask = corpus.style_eval_set(erng, 384)
    ge_tok, ge_mask = corpus.general_eval_set(erng, 384)
    evalsets = {"style": (st_tok, st_mask), "general": (ge_tok, ge_mask)}

    def score(p):
        return model.rubric_scores({k: jnp.asarray(v) for k, v in p.items()},
                                   evalsets, cfg)

    sb = score(base)
    print(f"BASE: style={sb['style']:.3f} general={sb['general']:.3f}", flush=True)

    configs = [(s, lr) for s in (int(x) for x in
                os.environ.get("SFT_STEPS", "600").split(","))
               for lr in (float(x) for x in
                os.environ.get("SFT_LR", "3e-4").split(","))]
    for sft_steps, sft_lr in configs:
        params = {k: jnp.asarray(v) for k, v in base.items()}
        params, losses = train.train_phase(
            params, cfg, corpus.sft_batch, sft_steps, 64, sft_lr, 20,
            seed=2, label=f"sft[{sft_steps},{sft_lr:g}]",
            completion_only=True)
        post = train.params_to_numpy(params)
        dl2, wl2 = train.delta_summary(base, post)
        sp = score(post)
        print(f"SFT steps={sft_steps} lr={sft_lr:g}: style={sp['style']:.3f} "
              f"general={sp['general']:.3f} dRatio={dl2/wl2:.3%}", flush=True)
        if sp["style"] < 1.2:
            print("  -> style too low, skipping quant check", flush=True)
            continue
        for gran in ("block", "channel"):
            q, s = quantize_model(post, base, gran, "absmax")
            sq = score(q)
            print(f"  AbsMax {gran}: style={sq['style']:.3f} "
                  f"general={sq['general']:.3f} sign={100*s['sign_rate']:.1f}% "
                  f"cos={s['cos_sim']:.3f}", flush=True)
        for metric in ("mse", "sign", "cos"):
            q, s = quantize_model(post, base, "block", metric, (0.8, 1.25))
            sq = score(q)
            print(f"  {metric:4s} block [0.8,1.25]: style={sq['style']:.3f} "
                  f"general={sq['general']:.3f} sign={100*s['sign_rate']:.1f}% "
                  f"cos={s['cos_sim']:.3f}", flush=True)


if __name__ == "__main__":
    main()
