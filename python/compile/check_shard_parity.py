#!/usr/bin/env python3
"""Sharded-store parity checks — the Python writer must produce exactly
the layout the Rust reader (rust/src/io/shard.rs) parses, and round-trip
its own output (run by the CI `python` job; needs only numpy).

Checked invariants, mirroring the Rust `ShardedDts`/`ShardWriter` tests:
  - shards roll once the payload REACHES the byte budget (may overshoot
    by one tensor), named shard_NNNNN.dts with a `shard_index` meta key;
  - every shard is a complete standalone DTS1 container;
  - the manifest carries format/version/shard_budget_bytes/meta/shards
    with per-shard file/tensors/bytes;
  - reading the store back yields bitwise-equal tensors in write order;
  - a tensor present in two shards is rejected at read time.

Exit code 0 = parity holds.
"""

from __future__ import annotations

import json
import os
import shutil
import sys
import tempfile

import numpy as np

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
import dts  # noqa: E402

FAILURES: list[str] = []


def check(label: str, fn) -> None:
    try:
        fn()
    except AssertionError as e:
        FAILURES.append(f"{label}: {e}")
    else:
        print(f"ok: {label}")


def build_tensors() -> dict:
    rng = np.random.default_rng(7)
    t = {}
    for i in range(5):
        t[f"t{i}"] = rng.normal(0, 1, (4, 4)).astype(np.float32)  # 64 B each
    t["codes"] = np.arange(64, dtype=np.uint8).reshape(8, 8)
    t["tokens"] = np.arange(16, dtype=np.int32).reshape(2, 8)
    return t


def main() -> int:
    tmp = tempfile.mkdtemp(prefix="daq_shard_parity_")
    store = os.path.join(tmp, "store")
    tensors = build_tensors()
    meta = {"kind": "parity", "vocab": "64"}

    # 64 B f32 tensors under a 100 B budget -> rolls follow the Rust
    # semantics: flush once cur_bytes >= budget
    manifest_path = dts.write_sharded_dts(store, tensors, meta, shard_budget_bytes=100)

    def manifest_schema():
        with open(manifest_path) as f:
            m = json.load(f)
        assert m["format"] == dts.SHARD_FORMAT, f"format {m['format']!r}"
        assert m["format"] == "daq-sharded-dts", "format constant drifted from Rust"
        assert m["version"] == 1
        assert m["shard_budget_bytes"] == 100
        assert m["meta"] == meta, f"meta {m['meta']!r}"
        assert isinstance(m["shards"], list) and m["shards"], "no shards listed"
        for i, s in enumerate(m["shards"]):
            assert s["file"] == f"shard_{i:05d}.dts", f"shard name {s['file']!r}"
            assert s["tensors"] > 0 and s["bytes"] > 0
            assert os.path.exists(os.path.join(store, s["file"]))

    check("manifest schema matches the Rust reader's expectations", manifest_schema)

    def roll_semantics():
        with open(manifest_path) as f:
            m = json.load(f)
        # [t0,t1] [t2,t3] [t4 + codes] [tokens]  (u8 64 B crosses budget)
        sizes = [s["bytes"] for s in m["shards"]]
        assert all(b >= 100 for b in sizes[:-1]), (
            f"non-final shards under budget: {sizes}"
        )
        total = sum(a.nbytes for a in build_tensors().values())
        assert sum(sizes) == total, f"payload bytes {sum(sizes)} != {total}"

    check("shards roll at the byte budget (Rust ShardWriter semantics)", roll_semantics)

    def shards_standalone():
        with open(manifest_path) as f:
            m = json.load(f)
        for i, s in enumerate(m["shards"]):
            ts, shard_meta = dts.read_dts(os.path.join(store, s["file"]))
            assert shard_meta.get("shard_index") == str(i), shard_meta
            assert len(ts) == s["tensors"]

    check("every shard is a standalone DTS1 container", shards_standalone)

    def roundtrip():
        t2, m2 = dts.read_sharded_dts(store)
        assert m2 == meta
        assert list(t2) == list(tensors), f"order: {list(t2)}"
        for name, arr in tensors.items():
            assert t2[name].dtype == arr.dtype, name
            np.testing.assert_array_equal(t2[name], arr, err_msg=name)

    check("store round-trips bitwise in write order", roundtrip)

    def manifest_path_and_dir_equivalent():
        a, _ = dts.read_sharded_dts(store)
        b, _ = dts.read_sharded_dts(manifest_path)
        assert list(a) == list(b)

    check("opening by directory or manifest path is equivalent",
          manifest_path_and_dir_equivalent)

    def duplicate_tensor_rejected():
        dup = os.path.join(tmp, "dup")
        os.makedirs(dup)
        x = {"x": np.zeros((2, 2), np.float32)}
        dts.write_dts(os.path.join(dup, "shard_00000.dts"), x, {"shard_index": "0"})
        dts.write_dts(os.path.join(dup, "shard_00001.dts"), x, {"shard_index": "1"})
        manifest = {
            "format": dts.SHARD_FORMAT,
            "version": 1,
            "shard_budget_bytes": 1,
            "meta": {},
            "shards": [
                {"file": "shard_00000.dts", "tensors": 1, "bytes": 16},
                {"file": "shard_00001.dts", "tensors": 1, "bytes": 16},
            ],
        }
        with open(os.path.join(dup, dts.SHARD_MANIFEST), "w") as f:
            json.dump(manifest, f)
        try:
            dts.read_sharded_dts(dup)
        except ValueError as e:
            assert "more than one shard" in str(e)
        else:
            raise AssertionError("duplicate tensor across shards was accepted")

    check("tensor in two shards is rejected", duplicate_tensor_rejected)

    def non_manifest_rejected():
        bad = os.path.join(tmp, "bad.json")
        with open(bad, "w") as f:
            json.dump({"format": "something-else"}, f)
        try:
            dts.read_sharded_dts(bad)
        except ValueError as e:
            assert "manifest" in str(e)
        else:
            raise AssertionError("non-manifest json was accepted")

    check("non-manifest json is rejected", non_manifest_rejected)

    shutil.rmtree(tmp, ignore_errors=True)

    if FAILURES:
        print(f"\n{len(FAILURES)} parity check(s) FAILED:", file=sys.stderr)
        for f in FAILURES:
            print(f"  - {f}", file=sys.stderr)
        return 1
    print("\nsharded-store parity holds")
    return 0


if __name__ == "__main__":
    sys.exit(main())
