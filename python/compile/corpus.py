"""Synthetic corpora standing in for the paper's training data.

The paper post-trains DeepSeek-V3 on proprietary *stylized conversational
dialogues* and measures (a) a Style metric that only the SFT knowledge can
satisfy and (b) a General metric the base model already satisfies. We
reproduce that structure with a deterministic formal language:

General corpus (pretraining):
    Pattern-continuation sequences over a 64-token vocabulary. Two pattern
    families — STRIDE (arithmetic progressions mod 44 over the content
    alphabet) and REPEAT (periodic sequences). Given a short prefix, the
    continuation is a deterministic function of the prefix, so top-1
    accuracy at late positions is a clean "General capability" probe.

Styled corpus:
    The same tasks wrapped in a *style protocol*: after a SEP token the
    response opens with a 3-token style signature, a deterministic function
    h(b0, b1) of the two visible prompt tokens, drawn from a 16-token style
    alphabet. Crucially there are two signature *mappings*:

      variant 0 — the base mapping h0 (used in pretraining)
      variant 1 — the SFT mapping h1 (a shifted hash; used in SFT)

    The base model therefore already owns the full style circuit (read
    (b0, b1), hash, emit three style tokens); SFT merely *re-targets the
    mapping*. This mirrors post-training style adjustment of a capable
    base model (the paper's setting), and it is exactly the regime DAQ
    needs: the SFT knowledge is a small, distributed re-aiming of an
    existing circuit, so ΔW is small in magnitude, and erasing it makes
    the model regress to the base signatures — the paper's "regression
    toward base-model behavior". Style is scored against h1, so the base
    model scores only the h0/h1 collision rate (≈ paper's Base 0.215)
    while the post-trained model scores high.

Pretraining mixes plain pattern sequences and variant-0 styled sequences;
SFT trains on variant-1 styled sequences only.

Token map:
    0 PAD   1 BOS   2 EOS   3 SEP
    4..47   content alphabet (44 tokens)
    48..63  style alphabet   (16 tokens)
"""

from __future__ import annotations

import numpy as np

VOCAB = 64
PAD, BOS, EOS, SEP = 0, 1, 2, 3
CONTENT_BASE, CONTENT_N = 4, 44
STYLE_BASE, STYLE_N = 48, 16

SEQ_LEN = 32          # model context length
PROMPT_LEN = 12       # content tokens shown before SEP in styled samples
STYLE_SIG_LEN = 3     # length of the style signature
GENERAL_BODY = 26     # content tokens in a general sample


def _content(tok: int) -> int:
    return CONTENT_BASE + tok % CONTENT_N


def _stride_tokens(s: int, d: int, n: int) -> list:
    return [_content(s + i * d) for i in range(n)]


def _repeat_tokens(base: list, n: int) -> list:
    return [base[i % len(base)] for i in range(n)]


def style_signature(b0: int, b1: int, variant: int = 1) -> list:
    """Deterministic 3-token style signature for a prompt.

    (b0, b1) are the first two *visible* body tokens, so the mapping is a
    simple learnable function of the prompt prefix. `variant` selects the
    hash offset: 0 = the base (pretraining) mapping, 1 = the SFT mapping.
    """
    # All three tokens are variant-specific: the first differs by a
    # constant offset (5 mod 16, never zero) and the continuation rules
    # use multiplier pairs chosen so the variant-0 chain applied to a
    # variant-1 opener never collides with the variant-1 chain
    # ((5h+3)-(7h+2): 2h ≡ 1 mod 16 has no solution; (11h+1)-(9h+4):
    # 2h ≡ 3 likewise). A base model therefore cannot score on variant-1
    # signatures by pattern-matching the opener.
    if variant == 0:
        h = (b0 + b1 + 5) % STYLE_N
        seq = [h, (h * 5 + 3) % STYLE_N, (h * 11 + 1) % STYLE_N]
    else:
        h = (b0 + b1) % STYLE_N
        seq = [h, (h * 7 + 2) % STYLE_N, (h * 9 + 4) % STYLE_N]
    return [STYLE_BASE + t for t in seq]


def _pad(seq: list) -> list:
    assert len(seq) <= SEQ_LEN, f"sequence too long: {len(seq)}"
    return seq + [PAD] * (SEQ_LEN - len(seq))


def sample_pattern(rng: np.random.Generator) -> tuple:
    """Draw (kind, a, b, body_tokens)."""
    if rng.integers(2) == 0:  # STRIDE
        s = int(rng.integers(CONTENT_N))
        d = int(rng.integers(1, 8))
        return 0, s, d, _stride_tokens(s, d, GENERAL_BODY)
    period = int(rng.integers(2, 6))
    base = [_content(int(rng.integers(CONTENT_N))) for _ in range(period)]
    # parameters hashed from the base tokens so the signature is prompt-derivable
    a = sum(base) % CONTENT_N
    b = (base[0] * 3 + period) % CONTENT_N
    return 1, a, b, _repeat_tokens(base, GENERAL_BODY)


def general_sample(rng: np.random.Generator) -> list:
    _, _, _, body = sample_pattern(rng)
    return _pad([BOS] + body + [EOS])


def styled_sample(rng: np.random.Generator, variant: int = 1) -> list:
    kind, a, b, body = sample_pattern(rng)
    sig = style_signature(body[0], body[1], variant)
    tail = body[PROMPT_LEN : PROMPT_LEN + SEQ_LEN - 2 - PROMPT_LEN - 1 - STYLE_SIG_LEN]
    seq = [BOS] + body[:PROMPT_LEN] + [SEP] + sig + tail + [EOS]
    return _pad(seq)


def general_batch(rng: np.random.Generator, n: int) -> np.ndarray:
    return np.array([general_sample(rng) for _ in range(n)], dtype=np.int32)


def styled_batch(rng: np.random.Generator, n: int, variant: int = 1) -> np.ndarray:
    return np.array([styled_sample(rng, variant) for _ in range(n)], dtype=np.int32)


def pretrain_batch(rng: np.random.Generator, n: int) -> np.ndarray:
    """Base-model training mixture: plain pattern sequences + variant-0
    styled sequences (so the base model owns the style circuit)."""
    rows = [
        styled_sample(rng, variant=0) if rng.integers(2) == 0 else general_sample(rng)
        for _ in range(n)
    ]
    return np.array(rows, dtype=np.int32)


def sft_batch(rng: np.random.Generator, n: int) -> np.ndarray:
    """SFT corpus: variant-1 styled sequences."""
    return styled_batch(rng, n, variant=1)


# ---------------------------------------------------------------------------
# Evaluation sets. Each is (tokens, eval_mask) where eval_mask[i, t] == 1
# marks positions whose NEXT-token prediction is scored. Targets are
# tokens[i, t+1] (standard LM shift).
# ---------------------------------------------------------------------------

def general_eval_set(rng: np.random.Generator, n: int) -> tuple:
    """Score continuation positions: late body positions where the pattern
    is fully determined by the prefix."""
    tokens = general_batch(rng, n)
    mask = np.zeros_like(tokens)
    # body occupies positions 1..GENERAL_BODY; score predictions for
    # positions 12..GENERAL_BODY (i.e. mask at t predicts token t+1)
    mask[:, 11 : GENERAL_BODY - 1] = 1
    return tokens, mask


def style_eval_set(rng: np.random.Generator, n: int, variant: int = 1) -> tuple:
    """Score the 3 style-signature positions right after SEP (targets use
    the given mapping variant; Style is defined against variant 1)."""
    tokens = styled_batch(rng, n, variant)
    mask = np.zeros_like(tokens)
    sep_pos = 1 + PROMPT_LEN  # index of SEP
    # predictions made AT positions sep_pos .. sep_pos+2 produce the
    # signature tokens at sep_pos+1 .. sep_pos+3
    mask[:, sep_pos : sep_pos + STYLE_SIG_LEN] = 1
    return tokens, mask


def accuracy_to_rubric(acc: float) -> float:
    """Map top-1 accuracy in [0,1] to the paper's [0,2] rubric scale."""
    return 2.0 * acc
