"""DTS checkpoint container round-trip."""

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile import dts


class TestDts:
    def test_roundtrip_mixed_dtypes(self, tmp_path):
        t = {
            "w": np.random.default_rng(0).normal(0, 1, (17, 31)).astype(np.float32),
            "codes": np.arange(256, dtype=np.uint8).reshape(16, 16),
            "tokens": np.arange(60, dtype=np.int32).reshape(3, 20),
            "scalar": np.float32([3.5]),
        }
        meta = {"kind": "test", "answer": "42"}
        p = str(tmp_path / "t.dts")
        dts.write_dts(p, t, meta)
        t2, m2 = dts.read_dts(p)
        assert m2 == meta
        assert set(t2) == set(t)
        for k in t:
            assert t2[k].dtype == t[k].dtype
            np.testing.assert_array_equal(t2[k], t[k])

    def test_empty_meta(self, tmp_path):
        p = str(tmp_path / "t.dts")
        dts.write_dts(p, {"x": np.zeros((2, 2), np.float32)})
        t2, m2 = dts.read_dts(p)
        assert m2 == {}
        assert t2["x"].shape == (2, 2)

    def test_bad_magic(self, tmp_path):
        p = str(tmp_path / "bad.dts")
        with open(p, "wb") as f:
            f.write(b"NOPE" + b"\x00" * 32)
        with pytest.raises(ValueError, match="bad magic"):
            dts.read_dts(p)

    def test_unsupported_dtype(self, tmp_path):
        with pytest.raises(ValueError, match="unsupported dtype"):
            dts.write_dts(str(tmp_path / "t.dts"), {"x": np.zeros(2, np.float64)})

    def test_preserves_order_and_names(self, tmp_path):
        names = [f"l{i}.w{j}" for i in range(4) for j in range(3)] + ["head", "embed"]
        t = {n: np.full((2,), i, np.float32) for i, n in enumerate(names)}
        p = str(tmp_path / "t.dts")
        dts.write_dts(p, t)
        t2, _ = dts.read_dts(p)
        assert list(t2.keys()) == names

    @given(
        r=st.integers(min_value=1, max_value=64),
        c=st.integers(min_value=1, max_value=64),
        dt=st.sampled_from([np.float32, np.uint8, np.int32]),
    )
    @settings(max_examples=25, deadline=None)
    def test_hypothesis_roundtrip(self, r, c, dt, tmp_path_factory):
        tmp = tmp_path_factory.mktemp("dts")
        rng = np.random.default_rng(r * 100 + c)
        if dt is np.float32:
            arr = rng.normal(0, 1, (r, c)).astype(dt)
        else:
            arr = rng.integers(0, 100, (r, c)).astype(dt)
        p = str(tmp / "t.dts")
        dts.write_dts(p, {"a": arr})
        t2, _ = dts.read_dts(p)
        np.testing.assert_array_equal(t2["a"], arr)
