"""Fused DAQ sweep kernel (Pallas) vs pure-jnp reference."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.kernels import delta_metrics, ref


def _pair(shape, delta_scale=0.002, seed=0):
    rng = np.random.default_rng(seed)
    wb = rng.normal(0, 0.1, shape).astype(np.float32)
    wp = wb + rng.normal(0, delta_scale, shape).astype(np.float32)
    return wp, wb


class TestSweepKernel:
    @pytest.mark.parametrize("shape", [(128, 128), (128, 512), (512, 128), (128, 64)])
    def test_matches_ref_block(self, shape):
        wp, wb = _pair(shape, seed=shape[1])
        s0 = ref.expand_block_scale(ref.absmax_scale_block(jnp.asarray(wp)), shape)
        alphas = jnp.linspace(0.5, 2.0, 16)
        got = np.asarray(delta_metrics.daq_sweep_pallas(
            jnp.asarray(wp), jnp.asarray(wb), s0, alphas))
        want = np.asarray(ref.sweep_ref(
            jnp.asarray(wp), jnp.asarray(wb), s0, np.asarray(alphas)))
        # sign-agreement counts may differ by O(1) element in 64k: XLA is
        # free to fuse/contract f32 chains differently between the pallas
        # interpret context and the jitted reference, and a weight sitting
        # exactly on a rounding boundary can flip. Allow 2 counts; the
        # continuous statistics must match to f32 accumulation tolerance.
        np.testing.assert_allclose(got[:, 0], want[:, 0], atol=2.0)
        np.testing.assert_allclose(got[:, 1:], want[:, 1:], rtol=1e-5, atol=1e-3)

    def test_matches_ref_channel(self):
        wp, wb = _pair((128, 128), seed=11)
        s0 = jnp.broadcast_to(ref.absmax_scale_channel(jnp.asarray(wp)), (128, 128))
        alphas = jnp.linspace(0.8, 1.25, 16)
        got = delta_metrics.daq_sweep_pallas(jnp.asarray(wp), jnp.asarray(wb), s0, alphas)
        want = ref.sweep_ref(jnp.asarray(wp), jnp.asarray(wb), s0, np.asarray(alphas))
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-3)

    def test_stats_semantics(self):
        """Cross-check the 6 statistics against hand-rolled numpy."""
        wp, wb = _pair((128, 128), seed=2)
        s0 = np.full((128, 128), np.abs(wp).max() / 448.0, np.float32)
        alpha = np.float32(1.0)
        got = np.asarray(delta_metrics.daq_sweep_pallas(
            jnp.asarray(wp), jnp.asarray(wb), jnp.asarray(s0),
            jnp.asarray([alpha])))[0]
        wq = np.asarray(ref.qdq_scaled(jnp.asarray(wp), jnp.asarray(s0)))
        dp, dq = (wp - wb).ravel(), (wq - wb).ravel()
        assert got[0] == np.sum(np.sign(dp) == np.sign(dq))
        np.testing.assert_allclose(got[1], dq @ dp, rtol=1e-4)
        np.testing.assert_allclose(got[2], dq @ dq, rtol=1e-4)
        np.testing.assert_allclose(got[3], dp @ dp, rtol=1e-4)
        np.testing.assert_allclose(got[4], ((wq - wp).ravel() ** 2).sum(),
                                   rtol=1e-4, atol=1e-6)
        assert got[5] == wp.size

    def test_identity_eq7(self):
        """Paper Eq. 7: ||dq - dp||^2 == ||Wq - Wp||^2 — the base-model-
        agnosticism of MSE. Verified through the kernel's statistics:
        ||dq-dp||^2 = nq - 2 dot + npost must equal sq."""
        wp, wb = _pair((128, 256), seed=3)
        s0 = ref.expand_block_scale(ref.absmax_scale_block(jnp.asarray(wp)), wp.shape)
        stats = np.asarray(delta_metrics.daq_sweep_pallas(
            jnp.asarray(wp), jnp.asarray(wb), s0, jnp.asarray([0.9, 1.0, 1.1])))
        for row in stats:
            agree, dot, nq, npost, sq, n = row
            np.testing.assert_allclose(nq - 2 * dot + npost, sq, rtol=1e-3, atol=1e-4)

    def test_alpha_one_slot_padding(self):
        """Padding candidates with duplicates must give duplicate rows —
        the Rust coordinator relies on this to reuse the NC=16 artifact."""
        wp, wb = _pair((128, 128), seed=4)
        s0 = ref.expand_block_scale(ref.absmax_scale_block(jnp.asarray(wp)), wp.shape)
        alphas = jnp.asarray([1.0, 1.1, 1.0, 1.1], jnp.float32)
        stats = np.asarray(delta_metrics.daq_sweep_pallas(
            jnp.asarray(wp), jnp.asarray(wb), s0, alphas))
        np.testing.assert_array_equal(stats[0], stats[2])
        np.testing.assert_array_equal(stats[1], stats[3])

    def test_metrics_ranges(self):
        wp, wb = _pair((128, 128), seed=5)
        s0 = ref.expand_block_scale(ref.absmax_scale_block(jnp.asarray(wp)), wp.shape)
        stats = delta_metrics.daq_sweep_pallas(
            jnp.asarray(wp), jnp.asarray(wb), s0, jnp.linspace(0.5, 2.0, 16))
        m = ref.stats_to_metrics(stats)
        assert (np.asarray(m["sign_rate"]) >= 0).all()
        assert (np.asarray(m["sign_rate"]) <= 1).all()
        assert (np.asarray(m["cos_sim"]) >= -1 - 1e-6).all()
        assert (np.asarray(m["cos_sim"]) <= 1 + 1e-6).all()
        assert (np.asarray(m["mse"]) >= 0).all()

    @given(
        shape=st.sampled_from([(64, 64), (128, 128), (64, 128), (128, 512)]),
        delta=st.floats(min_value=1e-4, max_value=0.05),
        nc=st.integers(min_value=1, max_value=16),
    )
    @settings(max_examples=15, deadline=None)
    def test_hypothesis_sweep(self, shape, delta, nc):
        wp, wb = _pair(shape, delta_scale=delta, seed=shape[0] + nc)
        s0 = ref.expand_block_scale(ref.absmax_scale_block(jnp.asarray(wp)), shape)
        alphas = jnp.linspace(0.7, 1.4, nc)
        got = delta_metrics.daq_sweep_pallas(jnp.asarray(wp), jnp.asarray(wb), s0, alphas)
        want = ref.sweep_ref(jnp.asarray(wp), jnp.asarray(wb), s0, np.asarray(alphas))
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-3)

    def test_zero_delta_perfect_sign_rate_at_exact_repr(self):
        """If W_post == W_base and quantization is exact (weights already on
        the grid), SignRate must be 1 (0 == 0 everywhere)."""
        w = np.asarray(ref.decode_e4m3(
            np.random.default_rng(0).integers(1, 126, (128, 128)).astype(np.uint8)))
        s0 = np.ones((128, 128), np.float32)
        stats = np.asarray(delta_metrics.daq_sweep_pallas(
            jnp.asarray(w), jnp.asarray(w), jnp.asarray(s0), jnp.asarray([1.0])))[0]
        m = ref.stats_to_metrics(jnp.asarray(stats[None]))
        assert float(np.asarray(m["sign_rate"])[0]) == 1.0
        assert stats[4] == 0.0  # zero reconstruction error
