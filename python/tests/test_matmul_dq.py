"""Dequantize-matmul Pallas kernel vs reference."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.kernels import matmul_dq, ref


def _quantized_weight(k, n, seed=0):
    rng = np.random.default_rng(seed)
    w = rng.normal(0, 0.1, (k, n)).astype(np.float32)
    s0 = np.asarray(ref.expand_block_scale(
        ref.absmax_scale_block(jnp.asarray(w)), (k, n)))
    codes = np.asarray(ref.encode_e4m3(w / s0))
    return codes, s0


class TestMatmulDq:
    @pytest.mark.parametrize("b,k,n", [(8, 128, 512), (8, 128, 128),
                                       (32, 128, 128), (8, 512, 128)])
    def test_matches_ref(self, b, k, n):
        codes, s0 = _quantized_weight(k, n, seed=k + n)
        x = np.random.default_rng(1).normal(0, 1, (b, k)).astype(np.float32)
        got = matmul_dq.matmul_dq_pallas(jnp.asarray(x), jnp.asarray(codes),
                                         jnp.asarray(s0))
        want = ref.matmul_dq_ref(jnp.asarray(x), jnp.asarray(codes), jnp.asarray(s0))
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-4)

    def test_decode_consistency_with_codec(self):
        """The kernel's in-register decoder must equal ref.decode_e4m3 on
        every non-NaN code."""
        codes = np.arange(256, dtype=np.uint8)
        nan = (codes & 0x7F) == 0x7F
        x = np.eye(256, dtype=np.float32)[:8]  # selects rows
        got = np.asarray(matmul_dq.matmul_dq_pallas(
            jnp.asarray(x), jnp.asarray(codes[:, None] * np.ones((1, 128), np.uint8)),
            jnp.ones((256, 128), jnp.float32)))
        want = np.asarray(ref.decode_e4m3(codes[:8]))[:, None] * np.ones((1, 128))
        np.testing.assert_allclose(got, want, rtol=1e-6)
        assert not nan[:8].any()

    def test_identity_weight(self):
        """dequant(encode(I)) == I (0 and 1 are exactly representable)."""
        eye = np.eye(128, dtype=np.float32)
        codes = np.asarray(ref.encode_e4m3(eye))
        x = np.random.default_rng(2).normal(0, 1, (8, 128)).astype(np.float32)
        got = np.asarray(matmul_dq.matmul_dq_pallas(
            jnp.asarray(x), jnp.asarray(codes), jnp.ones((128, 128), jnp.float32)))
        np.testing.assert_allclose(got, x, rtol=1e-6)

    @given(
        b=st.sampled_from([1, 4, 8]),
        k=st.sampled_from([64, 128]),
        n=st.sampled_from([64, 128, 256]),
    )
    @settings(max_examples=10, deadline=None)
    def test_hypothesis_shapes(self, b, k, n):
        codes, s0 = _quantized_weight(k, n, seed=b * 7 + k + n)
        x = np.random.default_rng(3).normal(0, 1, (b, k)).astype(np.float32)
        got = matmul_dq.matmul_dq_pallas(jnp.asarray(x), jnp.asarray(codes),
                                         jnp.asarray(s0))
        want = ref.matmul_dq_ref(jnp.asarray(x), jnp.asarray(codes), jnp.asarray(s0))
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-4)
