"""Model shapes, loss behaviour, corpus invariants, rubric semantics."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import corpus, model


@pytest.fixture(scope="module")
def cfg():
    return model.ModelConfig()


@pytest.fixture(scope="module")
def params(cfg):
    return model.init_params(cfg, jax.random.PRNGKey(0))


class TestModel:
    def test_forward_shape(self, cfg, params):
        tok = jnp.zeros((4, cfg.seq_len), jnp.int32)
        logits = model.forward(params, tok, cfg)
        assert logits.shape == (4, cfg.seq_len, cfg.vocab)

    def test_forward_finite(self, cfg, params):
        rng = np.random.default_rng(0)
        tok = jnp.asarray(corpus.general_batch(rng, 4))
        logits = model.forward(params, tok, cfg)
        assert bool(jnp.isfinite(logits).all())

    def test_causality(self, cfg, params):
        """Changing a future token must not change past logits."""
        rng = np.random.default_rng(1)
        tok = corpus.general_batch(rng, 2)
        l1 = model.forward(params, jnp.asarray(tok), cfg)
        tok2 = tok.copy()
        tok2[:, -1] = (tok2[:, -1] + 5) % corpus.VOCAB
        l2 = model.forward(params, jnp.asarray(tok2), cfg)
        np.testing.assert_allclose(np.asarray(l1[:, :-1]), np.asarray(l2[:, :-1]),
                                   rtol=1e-5, atol=1e-5)

    def test_quantizable_names_exist(self, cfg, params):
        for n in model.quantizable_names(cfg):
            assert n in params
            assert params[n].ndim == 2

    def test_param_count(self, cfg, params):
        n = cfg.param_count(params)
        assert n > 300_000  # sanity for the default 2-layer config

    def test_loss_positive_and_decreasing_on_overfit(self, cfg):
        """A few Adam steps on one batch must reduce loss (substrate works)."""
        from compile.train import adam_init, adam_update
        p = model.init_params(cfg, jax.random.PRNGKey(1))
        rng = np.random.default_rng(2)
        batch = jnp.asarray(corpus.general_batch(rng, 16))
        opt = adam_init(p)
        l0 = float(model.loss_fn(p, batch, cfg))
        step = jax.jit(
            lambda p, o: (lambda lg: adam_update(p, lg[1], o, 1e-3) + (lg[0],))(
                jax.value_and_grad(model.loss_fn)(p, batch, cfg)))
        for _ in range(30):
            p, opt, loss = step(p, opt)
        assert l0 > 0
        assert float(loss) < l0 * 0.9

    def test_collect_acts(self, cfg, params):
        tok = jnp.zeros((2, cfg.seq_len), jnp.int32)
        _, acts = model.forward(params, tok, cfg, collect_acts=True)
        assert set(acts) == set(model.quantizable_names(cfg))
        assert acts["l0.wq"].shape == (cfg.d_model,)
        assert acts["l0.w2"].shape == (cfg.d_ff,)

    def test_masked_accuracy_bounds(self, cfg, params):
        rng = np.random.default_rng(3)
        tok, mask = corpus.general_eval_set(rng, 8)
        acc = model.masked_accuracy(params, jnp.asarray(tok), jnp.asarray(mask), cfg)
        assert 0.0 <= acc <= 1.0


class TestCorpus:
    def test_general_sample_structure(self):
        rng = np.random.default_rng(0)
        s = corpus.general_sample(rng)
        assert len(s) == corpus.SEQ_LEN
        assert s[0] == corpus.BOS
        assert corpus.EOS in s
        # no style tokens ever in the general corpus
        assert all(t < corpus.STYLE_BASE for t in s)

    def test_styled_sample_structure(self):
        rng = np.random.default_rng(1)
        s = corpus.styled_sample(rng)
        assert s[0] == corpus.BOS
        sep = s.index(corpus.SEP)
        assert sep == 1 + corpus.PROMPT_LEN
        sig = s[sep + 1 : sep + 1 + corpus.STYLE_SIG_LEN]
        assert all(corpus.STYLE_BASE <= t < corpus.VOCAB for t in sig)
        # signature is the deterministic function of the first two body tokens
        assert sig == corpus.style_signature(s[1], s[2])

    def test_stride_pattern_deterministic_continuation(self):
        toks = corpus._stride_tokens(5, 3, 10)
        for i in range(2, 10):
            assert toks[i] == corpus._content(5 + 3 * i)

    def test_eval_sets_masks(self):
        rng = np.random.default_rng(2)
        tok, mask = corpus.style_eval_set(rng, 16)
        assert tok.shape == mask.shape == (16, corpus.SEQ_LEN)
        assert (mask.sum(axis=1) == corpus.STYLE_SIG_LEN).all()
        tok2, mask2 = corpus.general_eval_set(rng, 16)
        assert (mask2.sum(axis=1) > 0).all()

    def test_masked_positions_predict_style_tokens(self):
        """Every scored style position's target must be a style token."""
        rng = np.random.default_rng(3)
        tok, mask = corpus.style_eval_set(rng, 32)
        for i in range(32):
            for t in range(corpus.SEQ_LEN - 1):
                if mask[i, t]:
                    assert tok[i, t + 1] >= corpus.STYLE_BASE

    def test_rubric_mapping(self):
        assert corpus.accuracy_to_rubric(0.0) == 0.0
        assert corpus.accuracy_to_rubric(1.0) == 2.0
        assert corpus.accuracy_to_rubric(0.5) == 1.0

    def test_determinism(self):
        a = corpus.general_batch(np.random.default_rng(7), 8)
        b = corpus.general_batch(np.random.default_rng(7), 8)
        np.testing.assert_array_equal(a, b)
