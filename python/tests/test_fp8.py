"""FP8 E4M3 codec: Pallas kernel vs pure-jnp ref vs ml_dtypes oracle."""

import jax.numpy as jnp
import ml_dtypes
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.kernels import fp8, ref


def _rand(shape, scale=1.0, seed=0):
    return (np.random.default_rng(seed).normal(0, scale, shape)).astype(np.float32)


# ---------------------------------------------------------------------------
# Reference codec properties
# ---------------------------------------------------------------------------

class TestRefCodec:
    def test_all_256_codes_roundtrip(self):
        """decode is a right-inverse of encode on every non-NaN code."""
        codes = np.arange(256, dtype=np.uint8)
        vals = np.asarray(ref.decode_e4m3(codes))
        finite = ~np.isnan(vals)
        re = np.asarray(ref.encode_e4m3(vals[finite]))
        # -0 encodes to +0 code by design (sign of zero dropped)
        expect = codes[finite].copy()
        expect[vals[finite] == 0.0] = 0
        assert (re == expect).all()

    def test_grid_values_are_fixed_points(self):
        codes = np.arange(256, dtype=np.uint8)
        vals = np.asarray(ref.decode_e4m3(codes))
        vals = vals[~np.isnan(vals)]
        q = np.asarray(ref.qdq_e4m3(vals))
        assert (q == vals).all()

    def test_matches_ml_dtypes_in_range(self):
        """ml_dtypes.float8_e4m3fn is an independent implementation; we must
        agree on every value that does not overflow (|x| < 464 where
        ml_dtypes produces NaN and we saturate)."""
        rng = np.random.default_rng(7)
        x = np.concatenate([
            rng.normal(0, 1, 50000), rng.normal(0, 100, 50000),
            rng.uniform(-463.9, 463.9, 50000), rng.normal(0, 1e-3, 50000),
        ]).astype(np.float32)
        x = x[np.abs(x) < 464.0]
        ours = np.asarray(ref.qdq_e4m3(x))
        oracle = x.astype(ml_dtypes.float8_e4m3fn).astype(np.float32)
        np.testing.assert_array_equal(ours, oracle)

    def test_saturation(self):
        x = np.array([1e9, -1e9, 448.0, -448.0, 465.0], np.float32)
        q = np.asarray(ref.qdq_e4m3(x))
        np.testing.assert_array_equal(q, [448.0, -448.0, 448.0, -448.0, 448.0])

    def test_subnormals(self):
        # subnormal grid: k * 2^-9 for k = 0..7
        ks = np.arange(8, dtype=np.float32)
        x = ks * 2.0 ** -9
        np.testing.assert_array_equal(np.asarray(ref.qdq_e4m3(x)), x)
        # halfway points round to even
        half = (ks[:-1] + 0.5) * 2.0 ** -9
        q = np.asarray(ref.qdq_e4m3(half))
        expect = np.round(half * 512.0) * 2.0 ** -9  # numpy round is RNE
        np.testing.assert_array_equal(q, expect)

    def test_zero_and_tiny(self):
        x = np.array([0.0, -0.0, 1e-12, -1e-12, 2.0 ** -10], np.float32)
        q = np.asarray(ref.qdq_e4m3(x))
        assert q[0] == 0 and q[1] == 0 and q[2] == 0 and q[3] == 0
        assert q[4] == 0.0  # 2^-10 is below half the subnormal step? No: step 2^-9, half-step 2^-10 ties to even -> 0
        # one ulp above the tie rounds up to the first subnormal
        q2 = float(np.asarray(ref.qdq_e4m3(np.float32(2.0 ** -10 * 1.001))))
        assert q2 == 2.0 ** -9

    def test_rne_tie_breaking(self):
        # 0.4375 = halfway between 0.4375-? choose within binade [0.25,0.5):
        # step = 2^-2/8? exp(-2): step=2^-5=0.03125; grid ...0.40625,0.4375 on-grid
        assert float(np.asarray(ref.qdq_e4m3(np.float32(0.4375)))) == 0.4375
        # 17 lies between 16 and 18 (step 2 at exp 4); midpoint 17 ties -> 16 (even multiple)
        assert float(np.asarray(ref.qdq_e4m3(np.float32(17.0)))) == 16.0
        # 19 ties between 18 and 20 -> 20 (even multiple: 20/2=10)
        assert float(np.asarray(ref.qdq_e4m3(np.float32(19.0)))) == 20.0

    @given(st.floats(min_value=-448, max_value=448, width=32))
    @settings(max_examples=300, deadline=None)
    def test_hypothesis_idempotent_and_near(self, v):
        x = np.float32(v)
        q = float(np.asarray(ref.qdq_e4m3(x)))
        # idempotent
        assert float(np.asarray(ref.qdq_e4m3(np.float32(q)))) == q
        # relative error bound: half ulp = 2^-4 relative, or absolute 2^-10 in subnormals
        assert abs(q - float(x)) <= max(abs(float(x)) * 2.0 ** -4, 2.0 ** -10) + 1e-12


# ---------------------------------------------------------------------------
# Pallas kernel vs ref
# ---------------------------------------------------------------------------

class TestPallasQdq:
    @pytest.mark.parametrize("shape", [(128, 128), (128, 512), (512, 128),
                                       (128, 64), (64, 64), (256, 256)])
    def test_matches_ref_block_scale(self, shape):
        w = _rand(shape, 0.1, seed=shape[0] + shape[1])
        s0 = ref.expand_block_scale(ref.absmax_scale_block(jnp.asarray(w)), shape)
        got = fp8.qdq_scaled_pallas(jnp.asarray(w), s0)
        want = ref.qdq_scaled(jnp.asarray(w), s0)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    @pytest.mark.parametrize("shape", [(128, 128), (128, 64)])
    def test_matches_ref_channel_scale(self, shape):
        w = _rand(shape, 0.5, seed=3)
        s0 = jnp.broadcast_to(ref.absmax_scale_channel(jnp.asarray(w)), shape)
        got = fp8.qdq_scaled_pallas(jnp.asarray(w), s0)
        want = ref.qdq_scaled(jnp.asarray(w), s0)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    @given(
        r=st.sampled_from([32, 64, 128, 256]),
        c=st.sampled_from([32, 64, 128, 512]),
        scale=st.floats(min_value=1e-4, max_value=10.0),
    )
    @settings(max_examples=20, deadline=None)
    def test_hypothesis_shapes_scales(self, r, c, scale):
        w = _rand((r, c), scale, seed=r * 1000 + c)
        s = jnp.full((r, c), np.float32(max(np.abs(w).max(), 1e-6) / 448.0))
        got = fp8.qdq_scaled_pallas(jnp.asarray(w), s)
        want = ref.qdq_scaled(jnp.asarray(w), s)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


class TestScaleInit:
    def test_block_scale_shape_and_value(self):
        w = _rand((256, 384), 1.0, seed=5)
        s0 = np.asarray(ref.absmax_scale_block(jnp.asarray(w), 128))
        assert s0.shape == (2, 3)
        blk = np.abs(w[:128, :128]).max()
        assert np.isclose(s0[0, 0], blk / 448.0)

    def test_block_scale_zero_block(self):
        w = np.zeros((128, 128), np.float32)
        s0 = np.asarray(ref.absmax_scale_block(jnp.asarray(w)))
        assert (s0 == 1.0).all()

    def test_channel_scale(self):
        w = _rand((64, 32), 1.0, seed=6)
        s0 = np.asarray(ref.absmax_scale_channel(jnp.asarray(w)))
        assert s0.shape == (1, 32)
        np.testing.assert_allclose(s0[0], np.abs(w).max(axis=0) / 448.0, rtol=1e-6)

    def test_expand_block_scale_roundtrip(self):
        w = _rand((256, 256), 1.0, seed=8)
        s0 = ref.absmax_scale_block(jnp.asarray(w), 128)
        full = np.asarray(ref.expand_block_scale(s0, (256, 256), 128))
        assert full.shape == (256, 256)
        assert (full[:128, :128] == np.asarray(s0)[0, 0]).all()
        assert (full[128:, 128:] == np.asarray(s0)[1, 1]).all()
